//! Per-worker shards under a **replication-budget spectrum** (paper §3.3,
//! generalized).
//!
//! The paper compares two extreme points: *vanilla* (DistDGL-style — each
//! worker stores only the in-edges of its own partition nodes, so every
//! non-local frontier node costs a remote sampling round) and *hybrid*
//! (the full topology replicated everywhere, so sampling is fully local).
//! Full replication cannot scale to billion-edge graphs, and vanilla
//! over-pays when most of the frontier is local, so this module makes
//! replication a **budget** instead of a binary: a [`ReplicationPolicy`]
//! spends a per-worker byte budget on a *partial* halo — local in-edges
//! always, then the adjacency lists of the highest-priority remote nodes
//! (boundary-BFS order, reference-weighted) until the budget is
//! exhausted. `byte_budget = Some(0)` degenerates to vanilla,
//! `byte_budget = None` (with unbounded hops) to hybrid, and everything
//! in between trades per-worker memory for data-dependent sampling
//! rounds (see `dist::sampling`).
//!
//! Replicated halo rows always carry a node's **complete** in-neighbor
//! list (never truncated), so sampling a halo node locally draws exactly
//! the neighbors its owner would have drawn — the bit-equality invariant
//! holds at every budget point.
//!
//! On top of the *static* halo, a [`TopologyView`] can carry a dynamic
//! **remote-adjacency cache** (see [`TopologyView::enable_cache`]): a
//! byte-budgeted [`SlabCache`] overlay that `try_neighbors` falls
//! through to when a node has no static row. Cached rows are complete
//! adjacency lists inserted by the distributed sampler's response decode
//! (`dist::sampling`), so a cached node samples bit-identically to a
//! local one — the same invariant, extended to the workload-adaptive
//! layer. The overlay is per-worker mutable state: clone the shard's
//! view (`shard.topology.clone()` is three `Arc` bumps) and enable the
//! cache on the clone.

use std::sync::Arc;

use crate::dist::cache::{CachePolicy, SlabCache};
use crate::graph::{Dataset, NodeId};

use super::book::PartitionBook;

/// Priority order in which the replication budget is spent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HaloPriority {
    /// Boundary-BFS order; within a hop, candidates referenced by the
    /// most already-covered adjacency entries come first (a proxy for how
    /// much frontier probability mass reaches them), ties broken by
    /// ascending node id. Deterministic.
    #[default]
    DegreeWeighted,
    /// Pure boundary-BFS discovery order: hop by hop, ascending node id
    /// within a hop. Deterministic.
    BfsOrder,
}

/// How much remote topology each worker replicates beyond its own
/// partition's in-edges — the axis that turns the paper's Vanilla/Hybrid
/// binary into a spectrum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicationPolicy {
    /// How many hops beyond the partition boundary the halo may grow.
    /// `0` forbids replication outright; `usize::MAX` leaves growth to
    /// the byte budget alone.
    pub hops: usize,
    /// Per-worker byte budget for replicated adjacency (8 bytes of row
    /// pointer + 4 bytes per in-edge for each replicated node). `None`
    /// is unlimited. The budget buys a *prefix* of the priority order —
    /// construction stops at the first candidate that does not fit — so
    /// a larger budget always replicates a superset of a smaller one,
    /// which makes rounds and bytes monotone along a budget sweep.
    pub byte_budget: Option<u64>,
    pub priority: HaloPriority,
}

impl ReplicationPolicy {
    /// The paper's vanilla arm: no replication, remote frontier nodes
    /// cost sampling rounds.
    pub fn vanilla() -> Self {
        Self { hops: 0, byte_budget: Some(0), priority: HaloPriority::DegreeWeighted }
    }

    /// The paper's hybrid arm: the full topology on every worker, zero
    /// sampling rounds.
    pub fn hybrid() -> Self {
        Self { hops: usize::MAX, byte_budget: None, priority: HaloPriority::DegreeWeighted }
    }

    /// A byte-budgeted point on the spectrum (hops unbounded).
    pub fn budgeted(bytes: u64) -> Self {
        Self { hops: usize::MAX, byte_budget: Some(bytes), priority: HaloPriority::DegreeWeighted }
    }

    /// Hop-bounded, byte-unbounded halo (e.g. `halo(1)` replicates the
    /// complete 1-hop boundary, which clears the first sampling exchange
    /// of every minibatch).
    pub fn halo(hops: usize) -> Self {
        Self { hops, byte_budget: None, priority: HaloPriority::DegreeWeighted }
    }

    /// Map an optional byte budget to a policy: `None` ⇒ hybrid,
    /// `Some(0)` ⇒ vanilla, `Some(b)` ⇒ budgeted.
    pub fn from_budget(budget: Option<u64>) -> Self {
        match budget {
            None => Self::hybrid(),
            Some(0) => Self::vanilla(),
            Some(b) => Self::budgeted(b),
        }
    }

    /// Full replication: every worker sees the whole topology.
    pub fn is_full(&self) -> bool {
        self.byte_budget.is_none() && self.hops == usize::MAX
    }

    /// Human-readable point label (report/CLI rows).
    pub fn label(&self) -> String {
        if self.is_full() {
            return "hybrid".into();
        }
        match self.byte_budget {
            Some(0) => "vanilla".into(),
            Some(b) if self.hops == usize::MAX => format!("budget:{b}"),
            Some(b) => format!("budget:{b}/h{}", self.hops),
            None => format!("halo:{}", self.hops),
        }
    }
}

/// What a worker can see of the graph topology: one CSR over the rows it
/// holds, with a `row_of` indirection from global node id to local row
/// (`u32::MAX` when the node is not materialized). Partial views lay out
/// the partition's own rows first, then replicated halo rows in policy
/// priority order; the full-replication view shares the graph's own
/// arrays (identity `row_of`) across all workers, one copy per process.
#[derive(Clone)]
pub struct TopologyView {
    indptr: Arc<Vec<usize>>,
    indices: Arc<Vec<NodeId>>,
    row_of: Arc<Vec<u32>>,
    /// Number of rows belonging to this worker's own partition.
    local_rows: usize,
    /// Number of replicated (halo) rows beyond the local ones.
    replicated_rows: usize,
    /// Bytes of adjacency attributable to replicated rows (8 + 4·deg per
    /// row) — the per-worker memory cost of the policy beyond vanilla.
    replicated_bytes: u64,
    /// True when every node of the graph has a row.
    full: bool,
    /// Dynamic remote-adjacency cache layered over the static rows —
    /// per-worker state (not shared through the `Arc`s above), absent
    /// unless [`Self::enable_cache`] was called on this clone.
    overlay: Option<Box<SlabCache<NodeId>>>,
}

/// Cached adjacency rows are charged like static halo rows: one row
/// pointer (8 bytes) plus 4 bytes per in-edge — see [`row_cost`].
const CACHE_ROW_OVERHEAD: u64 = 8;

impl TopologyView {
    /// In-neighbors of `v`, or `None` when `v` has no materialized row —
    /// the caller must resolve it through a remote sampling request.
    /// Static rows (local + halo prefix, via the `row_of` indirection)
    /// win; absent ones fall through to the cache overlay, whose rows
    /// are complete adjacency lists, so a hit is indistinguishable from
    /// a static row.
    #[inline]
    pub fn try_neighbors(&self, v: NodeId) -> Option<&[NodeId]> {
        let row = self.row_of[v as usize];
        if row == u32::MAX {
            self.overlay.as_ref()?.get(v)
        } else {
            Some(&self.indices[self.indptr[row as usize]..self.indptr[row as usize + 1]])
        }
    }

    /// Attach a dynamic remote-adjacency cache of `capacity_bytes` to
    /// this view. Part of the SPMD contract: every rank of a run must
    /// use the same capacity and policy (like the [`ReplicationPolicy`]
    /// itself), because the distributed sampler's wire format is keyed
    /// off whether caching is enabled.
    pub fn enable_cache(&mut self, capacity_bytes: u64, policy: CachePolicy) {
        self.overlay =
            Some(Box::new(SlabCache::new(policy, capacity_bytes, CACHE_ROW_OVERHEAD)));
    }

    /// Is the dynamic adjacency cache attached?
    #[inline]
    pub fn cache_enabled(&self) -> bool {
        self.overlay.is_some()
    }

    /// Wire-level admission threshold: a remote row is worth shipping
    /// whole iff its degree is **strictly below** the returned value
    /// (0 ⇒ nothing is admissible, including when no cache is attached).
    /// Derived from the cache's remaining budget — see
    /// [`SlabCache::admissible_len`].
    pub fn cache_admission_limit(&self) -> u32 {
        match &self.overlay {
            None => 0,
            Some(c) => c
                .admissible_len()
                .map_or(0, |len| (len as u64 + 1).min(u32::MAX as u64) as u32),
        }
    }

    /// Offer a full adjacency row to the overlay (no-op without a cache);
    /// returns whether it is now resident.
    pub fn cache_insert(&mut self, v: NodeId, row: &[NodeId]) -> bool {
        debug_assert_eq!(
            self.row_of[v as usize],
            u32::MAX,
            "node {v} already has a static row — caching it would shadow nothing"
        );
        match &mut self.overlay {
            None => false,
            Some(c) => c.insert(v, row),
        }
    }

    /// Resident overlay rows (0 without a cache).
    pub fn cached_rows(&self) -> usize {
        self.overlay.as_ref().map_or(0, |c| c.len())
    }

    /// Owned snapshot of the overlay's resident rows in slot order
    /// (empty without a cache). This is what the checkpoint subsystem
    /// persists so a resumed run can rewarm the cache instead of paying
    /// the cold epoch again; cache contents shape *traffic* only, never
    /// sampled MFGs, so replaying them is always curve-safe.
    pub fn cached_entries(&self) -> Vec<(NodeId, Vec<NodeId>)> {
        match &self.overlay {
            None => Vec::new(),
            Some(c) => c.iter().map(|(v, row)| (v, row.to_vec())).collect(),
        }
    }

    /// Bytes currently charged to the overlay (same 8 + 4·deg accounting
    /// as [`Self::replicated_bytes`]).
    pub fn cache_used_bytes(&self) -> u64 {
        self.overlay.as_ref().map_or(0, |c| c.used_bytes())
    }

    /// Does every node of the graph have a local row? (True under the
    /// hybrid policy; also reachable with a large enough finite budget.)
    #[inline]
    pub fn covers_all(&self) -> bool {
        self.full
    }

    pub fn local_rows(&self) -> usize {
        self.local_rows
    }

    pub fn replicated_rows(&self) -> usize {
        self.replicated_rows
    }

    /// Adjacency bytes spent on halo rows — must respect the policy's
    /// byte budget.
    pub fn replicated_bytes(&self) -> u64 {
        self.replicated_bytes
    }

    /// Bytes of adjacency data this worker holds (per-worker memory cost
    /// of the policy — the compromise the paper's §5 discusses). Shared
    /// full-replication arrays are charged in full to every worker, as
    /// each machine of the real deployment would hold its own copy.
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 4 + self.row_of.len() * 4
    }
}

/// Everything one worker owns.
pub struct WorkerShard {
    pub part: usize,
    pub num_parts: usize,
    pub book: Arc<PartitionBook>,
    /// The policy every shard of this run was built with. Collectives
    /// key their fast paths off this (uniform across ranks by the SPMD
    /// contract), **not** off per-rank view coverage — a finite budget
    /// can incidentally cover the whole graph on one rank but not
    /// another, and a coverage-keyed skip would desynchronize the world.
    pub policy: ReplicationPolicy,
    pub topology: TopologyView,
    /// Global ids of nodes whose features this worker stores (sorted).
    pub local_nodes: Vec<NodeId>,
    /// `feat_row[v]` = local feature row of global `v`, `u32::MAX` if remote.
    pub feat_row: Vec<u32>,
    /// Row-major `[local_nodes.len(), feat_dim]`.
    pub feats: Vec<f32>,
    pub feat_dim: usize,
    /// Labels, replicated (they are 4 bytes/node — negligible next to
    /// features; DistDGL replicates them inside the partition book too).
    pub labels: Arc<Vec<i32>>,
    /// Labeled nodes owned by this worker — its top-level seed pool.
    pub train_local: Vec<NodeId>,
}

impl WorkerShard {
    /// Feature row of a *local* node.
    #[inline]
    pub fn local_feat(&self, v: NodeId) -> &[f32] {
        let row = self.feat_row[v as usize];
        debug_assert_ne!(row, u32::MAX, "node {v} is not local to part {}", self.part);
        let f = self.feat_dim;
        &self.feats[row as usize * f..(row as usize + 1) * f]
    }

    #[inline]
    pub fn owns(&self, v: NodeId) -> bool {
        self.feat_row[v as usize] != u32::MAX
    }

    pub fn feature_bytes(&self) -> usize {
        self.feats.len() * 4
    }
}

/// Replication cost of materializing node `v`'s adjacency: one row
/// pointer slot plus its in-edge list.
#[inline]
fn row_cost(degree: usize) -> u64 {
    8 + 4 * degree as u64
}

/// Build one worker's topology view under `policy`: local in-edges
/// always, then budgeted boundary-BFS halo rows.
fn build_view(
    dataset: &Dataset,
    local_nodes: &[NodeId],
    policy: &ReplicationPolicy,
) -> TopologyView {
    let graph = &dataset.graph;
    let n = dataset.num_nodes();
    let (mut indptr, mut indices) = graph.induce_in_edges(local_nodes);
    let mut row_of = vec![u32::MAX; n];
    for (i, &v) in local_nodes.iter().enumerate() {
        row_of[v as usize] = i as u32;
    }
    let local_rows = local_nodes.len();
    let mut replicated_rows = 0usize;
    let mut replicated_bytes = 0u64;
    let mut budget_left = policy.byte_budget.unwrap_or(u64::MAX);

    // Boundary BFS: hop-1 candidates are the uncovered sources referenced
    // by local adjacency; hop k+1 candidates are the uncovered sources
    // referenced by rows added in hop k. Within a hop, candidates are
    // ordered by the policy's priority; the budget buys a prefix of that
    // order (construction stops at the first candidate that does not
    // fit), so replica sets are nested along any budget sweep.
    let mut current_rows: Vec<NodeId> = local_nodes.to_vec();
    let mut weight: Vec<u64> = vec![0; n];
    let mut touched: Vec<NodeId> = Vec::new();
    let mut hop = 0usize;
    'grow: while hop < policy.hops && budget_left > 0 && !current_rows.is_empty() {
        hop += 1;
        touched.clear();
        for &v in &current_rows {
            for &u in graph.neighbors(v) {
                if row_of[u as usize] == u32::MAX {
                    if weight[u as usize] == 0 {
                        touched.push(u);
                    }
                    weight[u as usize] += 1;
                }
            }
        }
        let mut cands: Vec<(u64, NodeId)> =
            touched.iter().map(|&u| (weight[u as usize], u)).collect();
        for &u in &touched {
            weight[u as usize] = 0; // reset for the next hop
        }
        match policy.priority {
            HaloPriority::DegreeWeighted => {
                cands.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
            }
            HaloPriority::BfsOrder => cands.sort_unstable_by_key(|&(_, u)| u),
        }
        let mut added: Vec<NodeId> = Vec::new();
        for (_, u) in cands {
            let cost = row_cost(graph.degree(u));
            if cost > budget_left {
                break 'grow; // prefix semantics: budget exhausted
            }
            budget_left -= cost;
            row_of[u as usize] = (local_rows + replicated_rows) as u32;
            indices.extend_from_slice(graph.neighbors(u));
            indptr.push(indices.len());
            replicated_rows += 1;
            replicated_bytes += cost;
            added.push(u);
        }
        current_rows = added;
    }

    let full = local_rows + replicated_rows == n;
    TopologyView {
        indptr: Arc::new(indptr),
        indices: Arc::new(indices),
        row_of: Arc::new(row_of),
        local_rows,
        replicated_rows,
        replicated_bytes,
        full,
        overlay: None,
    }
}

/// The shared arrays of a full-replication run: indptr, indices,
/// identity `row_of`, and the total adjacency bytes (one copy per
/// *process*; in the paper it is one copy per machine).
type FullArrays = (Arc<Vec<usize>>, Arc<Vec<NodeId>>, Arc<Vec<u32>>, u64);

fn full_replication_arrays(dataset: &Dataset) -> FullArrays {
    let g = &dataset.graph;
    let n = dataset.num_nodes();
    let total_adj_bytes: u64 = (0..n as NodeId).map(|v| row_cost(g.degree(v))).sum();
    (
        Arc::new(g.indptr().to_vec()),
        Arc::new(g.indices().to_vec()),
        Arc::new((0..n as u32).collect::<Vec<u32>>()),
        total_adj_bytes,
    )
}

fn build_one(
    dataset: &Dataset,
    book: &Arc<PartitionBook>,
    policy: &ReplicationPolicy,
    p: usize,
    labels: &Arc<Vec<i32>>,
    full_arrays: Option<&FullArrays>,
) -> WorkerShard {
    let n = dataset.num_nodes();
    let local_nodes = book.nodes_of(p);
    let mut feat_row = vec![u32::MAX; n];
    for (i, &v) in local_nodes.iter().enumerate() {
        feat_row[v as usize] = i as u32;
    }
    let f = dataset.feat_dim;
    let mut feats = Vec::with_capacity(local_nodes.len() * f);
    for &v in &local_nodes {
        feats.extend_from_slice(dataset.feat(v));
    }
    let topology = match full_arrays {
        Some((indptr, indices, row_of, total_adj_bytes)) => {
            let local_adj: u64 =
                local_nodes.iter().map(|&v| row_cost(dataset.graph.degree(v))).sum();
            TopologyView {
                indptr: Arc::clone(indptr),
                indices: Arc::clone(indices),
                row_of: Arc::clone(row_of),
                local_rows: local_nodes.len(),
                replicated_rows: n - local_nodes.len(),
                replicated_bytes: *total_adj_bytes - local_adj,
                full: true,
                overlay: None,
            }
        }
        None => build_view(dataset, &local_nodes, policy),
    };
    let train_local: Vec<NodeId> =
        dataset.train_ids.iter().copied().filter(|&v| book.part_of(v) == p).collect();
    WorkerShard {
        part: p,
        num_parts: book.num_parts(),
        book: Arc::clone(book),
        policy: *policy,
        topology,
        local_nodes,
        feat_row,
        feats,
        feat_dim: f,
        labels: Arc::clone(labels),
        train_local,
    }
}

/// Materialize all worker shards for a dataset under `policy` — the
/// in-process path (threads as machines). Full replication shares one
/// set of topology arrays across every shard of the process.
pub fn build_shards(
    dataset: &Dataset,
    book: &Arc<PartitionBook>,
    policy: &ReplicationPolicy,
) -> Vec<WorkerShard> {
    let labels = Arc::new(dataset.labels.clone());
    let full_arrays = policy.is_full().then(|| full_replication_arrays(dataset));
    (0..book.num_parts())
        .map(|p| build_one(dataset, book, policy, p, &labels, full_arrays.as_ref()))
        .collect()
}

/// Materialize **one** worker's shard — the multi-process path, where
/// each OS process (`fastsample worker --rank R`) holds only its own
/// rank's topology view, feature rows, and seed pool. Identical to
/// `build_shards(dataset, book, policy)[part]` by construction (both
/// call the same per-part builder), which is what keeps a multi-process
/// run bit-equal to the in-process harness.
pub fn build_shard(
    dataset: &Dataset,
    book: &Arc<PartitionBook>,
    policy: &ReplicationPolicy,
    part: usize,
) -> WorkerShard {
    assert!(
        part < book.num_parts(),
        "part {part} out of range for a {}-way partition",
        book.num_parts()
    );
    let labels = Arc::new(dataset.labels.clone());
    let full_arrays = policy.is_full().then(|| full_replication_arrays(dataset));
    build_one(dataset, book, policy, part, &labels, full_arrays.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{make_dataset, DatasetParams};
    use crate::partition::metis_like::{partition_graph, PartitionConfig};

    fn toy_dataset() -> Dataset {
        make_dataset(&DatasetParams {
            name: "shard-test".into(),
            num_nodes: 600,
            avg_degree: 8,
            feat_dim: 6,
            num_classes: 4,
            labeled_frac: 0.2,
            p_intra: 0.9,
            noise: 0.1,
            seed: 42,
        })
    }

    fn build(policy: ReplicationPolicy) -> (Dataset, Vec<WorkerShard>) {
        let d = toy_dataset();
        let book =
            Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
        let shards = build_shards(&d, &book, &policy);
        (d, shards)
    }

    #[test]
    fn shards_cover_all_nodes_exactly_once() {
        for policy in [
            ReplicationPolicy::vanilla(),
            ReplicationPolicy::budgeted(2048),
            ReplicationPolicy::hybrid(),
        ] {
            let (d, shards) = build(policy);
            let mut seen = vec![0u8; d.num_nodes()];
            for s in &shards {
                for &v in &s.local_nodes {
                    seen[v as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{policy:?}");
        }
    }

    #[test]
    fn features_match_dataset_rows() {
        let (d, shards) = build(ReplicationPolicy::hybrid());
        for s in &shards {
            for &v in s.local_nodes.iter().take(20) {
                assert_eq!(s.local_feat(v), d.feat(v));
                assert!(s.owns(v));
            }
        }
    }

    #[test]
    fn visibility_tracks_the_policy() {
        // Vanilla: a node is visible iff it is local, and visible rows
        // carry the full graph adjacency.
        let (d, shards) = build(ReplicationPolicy::vanilla());
        for s in &shards {
            assert_eq!(s.topology.replicated_rows(), 0);
            assert_eq!(s.topology.replicated_bytes(), 0);
            for v in 0..d.num_nodes() as NodeId {
                let visible = s.topology.try_neighbors(v).is_some();
                assert_eq!(visible, s.owns(v), "vanilla: node {v}");
                if visible {
                    assert_eq!(s.topology.try_neighbors(v).unwrap(), d.graph.neighbors(v));
                }
            }
        }
        // Hybrid: everything visible everywhere.
        let (d2, shards2) = build(ReplicationPolicy::hybrid());
        for s in &shards2 {
            assert!(s.topology.covers_all());
            for v in 0..d2.num_nodes() as NodeId {
                assert_eq!(s.topology.try_neighbors(v).unwrap(), d2.graph.neighbors(v));
            }
        }
        // Budgeted: local always visible, halo rows carry complete
        // adjacency (never truncated) — the bit-equality prerequisite.
        let (d3, shards3) = build(ReplicationPolicy::budgeted(4096));
        for s in &shards3 {
            assert!(s.topology.replicated_rows() > 0, "budget bought nothing");
            assert!(s.topology.replicated_bytes() <= 4096);
            for v in 0..d3.num_nodes() as NodeId {
                if s.owns(v) {
                    assert!(s.topology.try_neighbors(v).is_some());
                }
                if let Some(neigh) = s.topology.try_neighbors(v) {
                    assert_eq!(neigh, d3.graph.neighbors(v), "node {v}");
                }
            }
        }
    }

    #[test]
    fn one_hop_halo_covers_every_referenced_source() {
        // halo(1) with no byte cap must materialize every source that
        // appears in a local adjacency list — the property that clears
        // the first sampling exchange of a minibatch.
        let (d, shards) = build(ReplicationPolicy::halo(1));
        for s in &shards {
            for &v in &s.local_nodes {
                for &u in d.graph.neighbors(v) {
                    assert!(
                        s.topology.try_neighbors(u).is_some(),
                        "1-hop source {u} of local {v} not covered on part {}",
                        s.part
                    );
                }
            }
        }
    }

    #[test]
    fn budgets_buy_nested_prefixes() {
        // Larger budgets replicate a superset of smaller budgets (prefix
        // semantics), and memory/coverage grow monotonically.
        let d = toy_dataset();
        let book =
            Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
        let budgets = [0u64, 512, 2048, 8192, u64::MAX >> 1];
        let mut prev: Option<Vec<WorkerShard>> = None;
        for &b in &budgets {
            let shards = build_shards(&d, &book, &ReplicationPolicy::budgeted(b));
            if let Some(smaller) = &prev {
                for (lo, hi) in smaller.iter().zip(&shards) {
                    assert!(hi.topology.replicated_rows() >= lo.topology.replicated_rows());
                    assert!(hi.topology.replicated_bytes() >= lo.topology.replicated_bytes());
                    for v in 0..d.num_nodes() as NodeId {
                        if lo.topology.try_neighbors(v).is_some() {
                            assert!(
                                hi.topology.try_neighbors(v).is_some(),
                                "budget {b} dropped node {v} covered by a smaller budget"
                            );
                        }
                    }
                }
            }
            prev = Some(shards);
        }
    }

    #[test]
    fn construction_is_deterministic() {
        for policy in [ReplicationPolicy::budgeted(4096), ReplicationPolicy::halo(2)] {
            let (d, a) = build(policy);
            let (_, b) = build(policy);
            for (sa, sb) in a.iter().zip(&b) {
                assert_eq!(sa.topology.replicated_rows(), sb.topology.replicated_rows());
                for v in 0..d.num_nodes() as NodeId {
                    assert_eq!(
                        sa.topology.try_neighbors(v).is_some(),
                        sb.topology.try_neighbors(v).is_some(),
                        "{policy:?} node {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn cache_overlay_falls_through_static_rows() {
        let (d, shards) = build(ReplicationPolicy::vanilla());
        let s = &shards[0];
        let mut view = s.topology.clone();
        assert!(!view.cache_enabled());
        assert_eq!(view.cache_admission_limit(), 0);

        view.enable_cache(1 << 16, CachePolicy::Clock);
        assert!(view.cache_enabled());
        assert!(view.cache_admission_limit() > 0);

        // A remote node is invisible until its full row is cached; after
        // the insert it reads back exactly the graph's adjacency — the
        // bit-equality prerequisite, same as for static halo rows.
        let remote = (0..d.num_nodes() as NodeId)
            .find(|&v| !s.owns(v))
            .expect("vanilla shard must have remote nodes");
        assert!(view.try_neighbors(remote).is_none());
        assert!(view.cache_insert(remote, d.graph.neighbors(remote)));
        assert_eq!(view.try_neighbors(remote).unwrap(), d.graph.neighbors(remote));
        assert_eq!(view.cached_rows(), 1);
        assert_eq!(
            view.cache_used_bytes(),
            8 + 4 * d.graph.degree(remote) as u64
        );

        // Static rows always win (and the shard's own view is untouched —
        // the overlay is per-clone state).
        let local = s.local_nodes[0];
        assert_eq!(view.try_neighbors(local).unwrap(), d.graph.neighbors(local));
        assert!(s.topology.try_neighbors(remote).is_none());

        // Admission limits track the remaining budget under StaticDegree.
        let mut tight = s.topology.clone();
        tight.enable_cache(8 + 4 * 3, CachePolicy::StaticDegree);
        assert_eq!(tight.cache_admission_limit(), 4, "degrees 0..=3 admissible");
        let mut empty = s.topology.clone();
        empty.enable_cache(0, CachePolicy::StaticDegree);
        assert_eq!(empty.cache_admission_limit(), 0);
    }

    #[test]
    fn single_shard_build_matches_the_batch_build() {
        // The multi-process path (each rank builds only its own shard)
        // must be indistinguishable from indexing the in-process batch
        // build — the bit-equality prerequisite for `fastsample worker`.
        let d = toy_dataset();
        let book =
            Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(4)));
        for policy in [
            ReplicationPolicy::vanilla(),
            ReplicationPolicy::budgeted(2048),
            ReplicationPolicy::hybrid(),
        ] {
            let all = build_shards(&d, &book, &policy);
            for p in 0..4 {
                let one = build_shard(&d, &book, &policy, p);
                let batch = &all[p];
                assert_eq!(one.part, batch.part);
                assert_eq!(one.local_nodes, batch.local_nodes);
                assert_eq!(one.feat_row, batch.feat_row);
                assert_eq!(one.feats, batch.feats);
                assert_eq!(one.train_local, batch.train_local);
                assert_eq!(
                    one.topology.replicated_rows(),
                    batch.topology.replicated_rows(),
                    "{policy:?} part {p}"
                );
                assert_eq!(
                    one.topology.replicated_bytes(),
                    batch.topology.replicated_bytes()
                );
                for v in 0..d.num_nodes() as NodeId {
                    assert_eq!(
                        one.topology.try_neighbors(v),
                        batch.topology.try_neighbors(v),
                        "{policy:?} part {p} node {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn train_pools_partition_the_train_set() {
        let (d, shards) = build(ReplicationPolicy::hybrid());
        let total: usize = shards.iter().map(|s| s.train_local.len()).sum();
        assert_eq!(total, d.train_ids.len());
        for s in &shards {
            for &v in &s.train_local {
                assert_eq!(s.book.part_of(v), s.part);
            }
        }
    }

    #[test]
    fn memory_accounting_spans_the_spectrum() {
        let (d, vanilla) = build(ReplicationPolicy::vanilla());
        let (_, mid) = build(ReplicationPolicy::budgeted(4096));
        let (_, hybrid) = build(ReplicationPolicy::hybrid());
        // Hybrid: every worker is charged the full topology (plus the
        // shared identity row_of).
        for s in &hybrid {
            assert_eq!(
                s.topology.storage_bytes(),
                d.graph.storage_bytes() + d.num_nodes() * 4
            );
            assert!(s.topology.covers_all());
        }
        // The spectrum is strictly ordered per worker: vanilla < mid < hybrid.
        for ((v, m), h) in vanilla.iter().zip(&mid).zip(&hybrid) {
            assert!(v.topology.storage_bytes() < m.topology.storage_bytes());
            assert!(m.topology.storage_bytes() < h.topology.storage_bytes());
        }
        // Features always partition exactly.
        let total_feat: usize = vanilla.iter().map(|s| s.feats.len()).sum();
        assert_eq!(total_feat, d.feats.len());
    }

    #[test]
    fn policy_labels_and_constructors_line_up() {
        assert_eq!(ReplicationPolicy::vanilla().label(), "vanilla");
        assert_eq!(ReplicationPolicy::hybrid().label(), "hybrid");
        assert_eq!(ReplicationPolicy::budgeted(4096).label(), "budget:4096");
        assert_eq!(ReplicationPolicy::halo(1).label(), "halo:1");
        assert!(ReplicationPolicy::hybrid().is_full());
        assert!(!ReplicationPolicy::budgeted(u64::MAX >> 1).is_full());
        assert_eq!(ReplicationPolicy::from_budget(None), ReplicationPolicy::hybrid());
        assert_eq!(ReplicationPolicy::from_budget(Some(0)), ReplicationPolicy::vanilla());
        assert_eq!(
            ReplicationPolicy::from_budget(Some(7)),
            ReplicationPolicy::budgeted(7)
        );
    }
}
