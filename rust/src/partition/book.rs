//! Partition book: node → partition assignment plus the quality metrics
//! the paper's partitioning discussion cares about (edge cut, node/edge
//! balance, labeled-node balance).

use anyhow::{ensure, Result};

use crate::graph::{CscGraph, NodeId};

/// One partition's 1-hop replication frontier (see
/// [`PartitionBook::halo_profile`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HaloProfile {
    /// Distinct remote sources referenced by the partition's in-edges.
    pub boundary_nodes: usize,
    /// Bytes their complete adjacency lists would cost to replicate.
    pub halo_bytes: u64,
}

/// Immutable partition assignment for `num_parts` workers.
#[derive(Debug, Clone)]
pub struct PartitionBook {
    num_parts: usize,
    assignment: Vec<u16>,
}

impl PartitionBook {
    pub fn new(num_parts: usize, assignment: Vec<u16>) -> Result<Self> {
        ensure!(num_parts >= 1 && num_parts <= u16::MAX as usize);
        ensure!(
            assignment.iter().all(|&p| (p as usize) < num_parts),
            "assignment references partition >= num_parts"
        );
        Ok(Self { num_parts, assignment })
    }

    #[inline]
    pub fn part_of(&self, v: NodeId) -> usize {
        self.assignment[v as usize] as usize
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    pub fn num_nodes(&self) -> usize {
        self.assignment.len()
    }

    /// Nodes of one partition, in global-id order.
    pub fn nodes_of(&self, part: usize) -> Vec<NodeId> {
        (0..self.assignment.len() as NodeId).filter(|&v| self.part_of(v) == part).collect()
    }

    /// Per-partition node counts.
    pub fn node_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.num_parts];
        for &p in &self.assignment {
            c[p as usize] += 1;
        }
        c
    }

    /// Number of edges whose endpoints live in different partitions.
    pub fn edge_cut(&self, graph: &CscGraph) -> usize {
        let mut cut = 0usize;
        for v in 0..graph.num_nodes() as NodeId {
            let pv = self.part_of(v);
            cut += graph.neighbors(v).iter().filter(|&&u| self.part_of(u) != pv).count();
        }
        cut
    }

    /// Edge-cut fraction in `[0, 1]`.
    pub fn cut_fraction(&self, graph: &CscGraph) -> f64 {
        if graph.num_edges() == 0 {
            return 0.0;
        }
        self.edge_cut(graph) as f64 / graph.num_edges() as f64
    }

    /// Per-partition in-edge counts (edges owned by the dst partition,
    /// matching the paper's "all incoming edges to the partition nodes").
    pub fn edge_counts(&self, graph: &CscGraph) -> Vec<usize> {
        let mut c = vec![0usize; self.num_parts];
        for v in 0..graph.num_nodes() as NodeId {
            c[self.part_of(v)] += graph.degree(v);
        }
        c
    }

    /// Per-partition labeled-node counts (seed balance, paper §4).
    pub fn label_counts(&self, train_ids: &[NodeId]) -> Vec<usize> {
        let mut c = vec![0usize; self.num_parts];
        for &v in train_ids {
            c[self.part_of(v)] += 1;
        }
        c
    }

    /// Per-partition 1-hop halo profile: for each partition, the distinct
    /// remote sources referenced by its adjacency and the bytes their
    /// complete in-edge lists would cost to replicate (8 bytes of row
    /// pointer + 4 per in-edge). This is the natural denominator for a
    /// [`crate::partition::ReplicationPolicy`] byte budget: a budget of
    /// `halo_bytes` buys the whole 1-hop boundary.
    pub fn halo_profile(&self, graph: &CscGraph) -> Vec<HaloProfile> {
        let n = graph.num_nodes();
        let mut out = Vec::with_capacity(self.num_parts);
        // One pass per partition keeps memory at O(n) regardless of the
        // partition count (this is a setup-time metric, not a hot path).
        for p in 0..self.num_parts {
            let mut seen = vec![false; n];
            let mut prof = HaloProfile::default();
            for v in 0..n as NodeId {
                if self.part_of(v) != p {
                    continue;
                }
                for &u in graph.neighbors(v) {
                    if self.part_of(u) != p && !seen[u as usize] {
                        seen[u as usize] = true;
                        prof.boundary_nodes += 1;
                        prof.halo_bytes += 8 + 4 * graph.degree(u) as u64;
                    }
                }
            }
            out.push(prof);
        }
        out
    }

    /// max/mean imbalance of a count vector (1.0 = perfectly balanced).
    pub fn imbalance(counts: &[usize]) -> f64 {
        let total: usize = counts.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / counts.len() as f64;
        counts.iter().copied().max().unwrap_or(0) as f64 / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> CscGraph {
        // v <- v+1 for each v.
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        for v in 0..n {
            if v + 1 < n {
                indices.push((v + 1) as NodeId);
            }
            indptr.push(indices.len());
        }
        CscGraph::new(indptr, indices).unwrap()
    }

    #[test]
    fn contiguous_split_has_one_cut_edge() {
        let g = path_graph(10);
        let assignment: Vec<u16> = (0..10).map(|v| if v < 5 { 0 } else { 1 }).collect();
        let book = PartitionBook::new(2, assignment).unwrap();
        assert_eq!(book.edge_cut(&g), 1);
        assert_eq!(book.node_counts(), vec![5, 5]);
        assert_eq!(book.nodes_of(1), (5..10).collect::<Vec<_>>());
        assert!((PartitionBook::imbalance(&book.node_counts()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn interleaved_split_cuts_everything() {
        let g = path_graph(10);
        let assignment: Vec<u16> = (0..10).map(|v| (v % 2) as u16).collect();
        let book = PartitionBook::new(2, assignment).unwrap();
        assert_eq!(book.edge_cut(&g), 9);
        assert!((book.cut_fraction(&g) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn label_counts_follow_assignment() {
        let book = PartitionBook::new(2, vec![0, 0, 1, 1]).unwrap();
        assert_eq!(book.label_counts(&[0, 2, 3]), vec![1, 2]);
    }

    #[test]
    fn rejects_out_of_range_assignment() {
        assert!(PartitionBook::new(2, vec![0, 2]).is_err());
    }

    #[test]
    fn halo_profile_counts_distinct_remote_sources() {
        // Path 0 <- 1 <- ... <- 9, split 5|5: partition 0's only remote
        // source is node 5 (referenced by node 4); partition 1 references
        // nothing remote (its sources 6..9 are all local).
        let g = path_graph(10);
        let assignment: Vec<u16> = (0..10).map(|v| if v < 5 { 0 } else { 1 }).collect();
        let book = PartitionBook::new(2, assignment).unwrap();
        let prof = book.halo_profile(&g);
        assert_eq!(prof[0].boundary_nodes, 1);
        // Node 5 has one in-edge (from 6): 8 + 4*1 bytes.
        assert_eq!(prof[0].halo_bytes, 12);
        assert_eq!(prof[1], HaloProfile::default());
    }
}
