//! Coordination layer: experiment regenerators (one per paper
//! table/figure + ablations) and run-mode mapping. The `fastsample`
//! binary and the bench targets are thin wrappers over this module.

pub mod experiments;
