//! Experiment regenerators — one per table/figure of the paper plus the
//! ablations from DESIGN.md's experiment index. Each returns a printable
//! report; `fastsample report <id>` and the bench targets call these.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config;
use crate::dist::{CachePolicy, CommError, NetworkModel, RoundKind, TransportConfig};
use crate::graph::datasets::{self, IGBH_FULL, MAG240M, OGBN_PAPERS100M, OGBN_PRODUCTS};
use crate::graph::Dataset;
use crate::runtime::{Engine, Manifest, ModelRuntime};
use crate::sampling::rng::RngKey;
use crate::sampling::{sample_mfgs, KernelKind, MinibatchSchedule, SamplerWorkspace};
use crate::train::{pad_batch, train_distributed, ScheduleKind, TrainConfig};

/// Collapse per-rank fabric results, preferring a *root-cause* error
/// over cascade `PeerLost`s (a failing rank makes every peer fail with
/// "exited mid-collective" — same policy as the trainer's aggregation).
fn collect_ranks<T>(per_rank: Vec<std::result::Result<T, CommError>>) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(per_rank.len());
    let mut cascade: Option<anyhow::Error> = None;
    for (rank, r) in per_rank.into_iter().enumerate() {
        match r {
            Ok(v) => out.push(v),
            Err(e) => {
                let is_cascade = matches!(e, CommError::PeerLost { .. });
                let err = anyhow::Error::new(e).context(format!("worker {rank}"));
                if !is_cascade {
                    return Err(err);
                }
                cascade.get_or_insert(err);
            }
        }
    }
    match cascade {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

// ---------------------------------------------------------------------------
// Table 1 — dataset statistics.
// ---------------------------------------------------------------------------

/// Paper Table 1 (published graphs) side by side with the synthetic
/// analogs actually used in the benches.
pub fn table1(products_scale: f64, papers_scale: f64, seed: u64) -> Result<String> {
    let mut out = String::new();
    out.push_str("Table 1: graph datasets (published vs simulated analogs)\n\n");
    out.push_str(&format!(
        "{:<26} {:>12} {:>14} {:>10} {:>9} {:>9}\n",
        "graph", "# nodes", "# edges", "# feats", "# classes", "labeled"
    ));
    for g in [&OGBN_PRODUCTS, &OGBN_PAPERS100M] {
        out.push_str(&format!(
            "{:<26} {:>12} {:>14} {:>10} {:>9} {:>9}\n",
            g.name, g.num_nodes, g.num_edges, g.feat_dim, g.num_classes, "-"
        ));
    }
    for d in [
        datasets::products_sim(products_scale, seed),
        datasets::papers100m_sim(papers_scale, seed),
    ] {
        out.push_str(&format!(
            "{:<26} {:>12} {:>14} {:>10} {:>9} {:>9}\n",
            d.name,
            d.num_nodes(),
            d.num_edges(),
            d.feat_dim,
            d.num_classes,
            d.train_ids.len()
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 4 — storage breakdown: topology vs features.
// ---------------------------------------------------------------------------

/// Paper Fig 4: adjacency is a tiny fraction of total graph storage. The
/// published-metadata rows are the paper's own graphs; the sim rows are
/// *measured* from our in-memory structures.
pub fn fig4(products_scale: f64, papers_scale: f64, seed: u64) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig 4: graph storage breakdown (topology vs node features)\n\n");
    out.push_str(&format!(
        "{:<26} {:>14} {:>14} {:>10}\n",
        "graph", "topology", "features", "topo %"
    ));
    let row = |name: &str, topo: u64, feat: u64| {
        format!(
            "{:<26} {:>14} {:>14} {:>9.2}%\n",
            name,
            human_bytes(topo),
            human_bytes(feat),
            100.0 * topo as f64 / (topo + feat) as f64
        )
    };
    // The two graphs the paper plots, from published metadata.
    for g in [&MAG240M, &IGBH_FULL] {
        out.push_str(&row(g.name, g.topology_bytes(), g.feature_bytes()));
    }
    // The paper's training graphs + our sims, for context.
    for g in [&OGBN_PRODUCTS, &OGBN_PAPERS100M] {
        out.push_str(&row(g.name, g.topology_bytes(), g.feature_bytes()));
    }
    for d in [
        datasets::products_sim(products_scale, seed),
        datasets::papers100m_sim(papers_scale, seed),
    ] {
        out.push_str(&row(&d.name, d.topology_bytes() as u64, d.feature_bytes() as u64));
    }
    out.push_str(
        "\n(measured sim rows use the same CSC accounting as the published-metadata rows)\n",
    );
    Ok(out)
}

/// Fig-4 style memory table for a *partitioned* run: per-worker bytes
/// along the replication spectrum — vanilla, a halo-scale byte budget,
/// the complete 1-hop halo, and full replication (hybrid) — quantifying
/// the compromise the paper's §5 discusses as a dial, not a binary.
pub fn partition_memory(spec: &str, workers: usize, seed: u64) -> Result<String> {
    use crate::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
    use std::sync::Arc;
    let d = config::dataset(spec, seed)?;
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(workers)));
    let halo = book.halo_profile(&d.graph);
    let max_halo = halo.iter().map(|h| h.halo_bytes).max().unwrap_or(0).max(64);
    let mut out = String::new();
    out.push_str(&format!(
        "Per-worker memory, {} over {workers} workers (1-hop halo: up to {}/worker)\n\n\
         {:<16} {:>14} {:>14} {:>14} {:>14}\n",
        d.name,
        human_bytes(max_halo),
        "policy",
        "topology",
        "replicated",
        "features",
        "total"
    ));
    for policy in [
        ReplicationPolicy::vanilla(),
        ReplicationPolicy::budgeted(max_halo / 2),
        ReplicationPolicy::halo(1),
        ReplicationPolicy::hybrid(),
    ] {
        let shards = build_shards(&d, &book, &policy);
        let topo = shards.iter().map(|s| s.topology.storage_bytes() as u64).max().unwrap();
        let repl = shards.iter().map(|s| s.topology.replicated_bytes()).max().unwrap();
        let feat = shards.iter().map(|s| s.feature_bytes() as u64).max().unwrap();
        out.push_str(&format!(
            "{:<16} {:>14} {:>14} {:>14} {:>14}\n",
            policy.label(),
            human_bytes(topo),
            human_bytes(repl),
            human_bytes(feat),
            human_bytes(topo + feat)
        ));
    }
    out.push_str(&format!(
        "\nedge-cut fraction: {:.3}; label imbalance: {:.3}\n",
        book.cut_fraction(&d.graph),
        crate::partition::PartitionBook::imbalance(&book.label_counts(&d.train_ids))
    ));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Replication frontier — budget → rounds/bytes/memory (the spectrum).
// ---------------------------------------------------------------------------

/// Sweep the replication budget and measure, per minibatch, the sampling
/// rounds actually paid (data-dependent, `0..=2(L−1)`), the bytes moved,
/// and the per-worker adjacency memory — the frontier between the
/// paper's vanilla (2L+1 total rounds/minibatch) and hybrid (3) arms.
/// Pure communication structure: sampling + feature exchange + a
/// stand-in gradient sync, no AOT artifacts needed.
///
/// The function itself enforces the curve's invariants (monotone
/// non-increasing rounds, analytic endpoints) and fails loudly if they
/// break, so `fastsample report --id replication-frontier` doubles as a
/// regression check.
pub fn replication_frontier(spec: &str, workers: usize, seed: u64) -> Result<String> {
    use crate::dist::{fetch_features, run_workers_with, sample_mfgs_distributed, Counters};
    use crate::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
    use std::sync::Arc;

    let d = config::dataset(spec, seed)?;
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(workers)));
    let fanouts = [4usize, 3, 3]; // L = 3, the paper's depth
    let levels = fanouts.len();
    let batch = 32usize;
    let max_batches = 4u64;
    let key = RngKey::new(seed).fold(0xF0C5);

    // Budget sweep anchored on the measured 1-hop halo (the natural
    // scale): 0 (vanilla), a geometric ramp through it, then unlimited.
    let halo = book.halo_profile(&d.graph);
    let max_halo = halo.iter().map(|h| h.halo_bytes).max().unwrap_or(0).max(64);
    let budgets: Vec<Option<u64>> = vec![
        Some(0),
        Some(max_halo / 8),
        Some(max_halo / 2),
        Some(max_halo.saturating_mul(2)),
        None,
    ];

    let mut out = String::new();
    out.push_str(&format!(
        "Replication frontier: {} over {workers} workers, L={levels}, batch {batch} \
         (1-hop halo: up to {}/worker)\n\n\
         {:<16} {:>10} {:>10} {:>14} {:>14} {:>14} {:>9}\n",
        d.name,
        human_bytes(max_halo),
        "policy",
        "smpl rnd/b",
        "rounds/b",
        "sample bytes",
        "adjacency",
        "replicated",
        "coverage"
    ));

    let mut curve: Vec<(String, f64, f64)> = Vec::new();
    for b in budgets {
        let policy = ReplicationPolicy::from_budget(b);
        let shards = build_shards(&d, &book, &policy);
        let counters = Arc::new(Counters::default());
        let shards_ref = &shards;
        let done: Vec<Result<u64, CommError>> = run_workers_with(
            workers,
            NetworkModel::free(),
            Arc::clone(&counters),
            move |rank, comm| {
                let shard = &shards_ref[rank];
                let mut view = shard.topology.clone();
                let schedule = MinibatchSchedule::new(&shard.train_local, batch, key);
                let nb =
                    comm.all_reduce_min_u64(schedule.num_batches() as u64)?.min(max_batches);
                let mut ws = SamplerWorkspace::new();
                let mut feat = Vec::new();
                for bi in 0..nb {
                    let seeds = schedule.batch(bi as usize);
                    let mfgs = sample_mfgs_distributed(
                        comm,
                        shard,
                        &mut view,
                        seeds,
                        &fanouts,
                        key.fold(bi + 1),
                        &mut ws,
                        KernelKind::Fused,
                    )?;
                    fetch_features(comm, shard, &mfgs[0].src_nodes, None, &mut feat)?;
                    // Stand-in gradient sync: the report measures round
                    // structure, not model compute.
                    let mut grad = vec![0.0f32; 8];
                    comm.all_reduce_mean_f32(RoundKind::GradSync, &mut grad)?;
                }
                Ok(nb)
            },
        );
        let done: Vec<u64> = collect_ranks(done)?;
        let nb = done[0];
        ensure!(
            nb > 0,
            "dataset {spec:?} too small for batch {batch} over {workers} workers"
        );
        let s = counters.snapshot();
        let srpb = s.sampling_rounds() as f64 / nb as f64;
        let trpb = s.total_rounds() as f64 / nb as f64;
        let sample_bytes = (s.bytes_of(RoundKind::SampleRequest)
            + s.bytes_of(RoundKind::SampleResponse)) as f64
            / nb as f64;
        let topo = shards.iter().map(|s| s.topology.storage_bytes() as u64).max().unwrap();
        let repl = shards.iter().map(|s| s.topology.replicated_bytes()).max().unwrap();
        let n = d.num_nodes() as f64;
        let coverage = shards
            .iter()
            .map(|s| (s.topology.local_rows() + s.topology.replicated_rows()) as f64 / n)
            .fold(0.0, f64::max);
        out.push_str(&format!(
            "{:<16} {:>10.1} {:>10.1} {:>14} {:>14} {:>14} {:>8.1}%\n",
            policy.label(),
            srpb,
            trpb,
            human_bytes(sample_bytes as u64),
            human_bytes(topo),
            human_bytes(repl),
            100.0 * coverage
        ));
        curve.push((policy.label(), srpb, trpb));
    }

    // The curve's contract (acceptance criteria for the spectrum).
    for w in curve.windows(2) {
        ensure!(
            w[1].1 <= w[0].1 + 1e-9,
            "sampling rounds not monotone: {} {:.2} -> {} {:.2}",
            w[0].0,
            w[0].1,
            w[1].0,
            w[1].1
        );
    }
    let (first, last) = (curve.first().unwrap(), curve.last().unwrap());
    let analytic_vanilla = (2 * levels + 1) as f64;
    ensure!(
        (first.2 - analytic_vanilla).abs() < 1e-9,
        "vanilla endpoint {:.2} != analytic 2L+1 = {analytic_vanilla}",
        first.2
    );
    ensure!((last.2 - 3.0).abs() < 1e-9, "hybrid endpoint {:.2} != analytic 3", last.2);
    out.push_str(&format!(
        "\nendpoints: vanilla {:.1} rounds/batch (analytic 2L+1 = {}), hybrid {:.1} \
         (analytic 3); curve is monotone in the budget\n",
        first.2,
        2 * levels + 1,
        last.2
    ));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Cache decay — per-epoch SampleRequest traffic under the adjacency cache.
// ---------------------------------------------------------------------------

/// The adjacency cache's acceptance experiment: train several epochs of
/// pure sampling structure (no AOT artifacts) over **identical per-epoch
/// seed schedules and sampling keys** — deliberately, so the only thing
/// that changes between epochs is the cache state — and measure the
/// per-epoch `SampleRequest` bytes/rounds per arm.
///
/// The regenerator enforces the decay contract internally (`ensure!`),
/// so a successful run IS the acceptance check:
/// * cache off ⇒ every epoch pays identical request bytes;
/// * cache on ⇒ the per-epoch request-byte curve is **non-increasing**.
///   This holds for every *non-evicting* configuration — bounded
///   `StaticDegree` or any unbounded cache — because such a cache only
///   ever grows the set of locally answerable rows. (A byte-tight
///   `Clock` cache may legitimately churn and regress between epochs,
///   which is why no bounded-Clock arm belongs in this sweep.)
/// * an effectively unbounded cache ⇒ epochs after the first pay **zero**
///   sampling rounds and bytes — the whole miss set went resident, and
///   the round-skip vote clears every exchange.
pub fn cache_decay(
    spec: &str,
    workers: usize,
    seed: u64,
    transport: &TransportConfig,
) -> Result<String> {
    use crate::dist::{run_workers_on, sample_mfgs_distributed, CommStats, Counters};
    use crate::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
    use std::sync::Arc;

    let d = config::dataset(spec, seed)?;
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(workers)));
    // Vanilla replication: every cross-partition frontier node is a miss,
    // the regime where the cache has the most to absorb.
    let shards = build_shards(&d, &book, &ReplicationPolicy::vanilla());
    let fanouts = [4usize, 3, 3]; // L = 3, the paper's depth
    let batch = 32usize;
    let epochs = 4usize;
    let max_batches = 4u64;
    let key = RngKey::new(seed).fold(0xCAC4E);

    let unbounded = u64::MAX >> 1;
    // Every cached arm is non-evicting (bounded static or unbounded), the
    // regime where the non-increasing ensure below is a theorem; a
    // bounded Clock arm could churn and legitimately trip it.
    let arms: [(&str, u64, CachePolicy); 4] = [
        ("cache:0 (off)", 0, CachePolicy::StaticDegree),
        ("cache:2k static", 2 << 10, CachePolicy::StaticDegree),
        ("cache:inf static", unbounded, CachePolicy::StaticDegree),
        ("cache:inf clock", unbounded, CachePolicy::Clock),
    ];

    let mut out = String::new();
    out.push_str(&format!(
        "Cache decay: {} over {workers} workers ({transport} transport), vanilla replication, \
         L={}, batch {batch}, {epochs} epochs of identical seeds/keys\n\n{:<18} {:>7} {}\n",
        d.name,
        fanouts.len(),
        "arm",
        "epoch",
        "SampleRequest bytes (rounds)"
    ));

    for (label, cache_bytes, cache_policy) in arms {
        let counters = Arc::new(Counters::default());
        let shards_ref = &shards;
        let per_rank: Vec<Result<(u64, Vec<CommStats>), CommError>> = run_workers_on(
            transport,
            workers,
            NetworkModel::free(),
            Arc::clone(&counters),
            move |rank, comm| {
                let shard = &shards_ref[rank];
                let mut view = shard.topology.clone();
                if cache_bytes > 0 {
                    view.enable_cache(cache_bytes, cache_policy);
                }
                // One schedule, reused verbatim every epoch (no epoch key
                // fold): the workload repeats, only the cache state moves.
                let schedule = MinibatchSchedule::new(&shard.train_local, batch, key);
                let nb =
                    comm.all_reduce_min_u64(schedule.num_batches() as u64)?.min(max_batches);
                let mut ws = SamplerWorkspace::new();
                // Barrier-fenced epoch marks (see `Comm::fenced_snapshot`)
                // so the fabric-global counters slice into exact
                // per-epoch deltas.
                let mut marks = Vec::with_capacity(epochs + 1);
                for _epoch in 0..epochs {
                    marks.push(comm.fenced_snapshot()?);
                    for bi in 0..nb {
                        let seeds = schedule.batch(bi as usize);
                        let mfgs = sample_mfgs_distributed(
                            comm,
                            shard,
                            &mut view,
                            seeds,
                            &fanouts,
                            key.fold(bi + 1),
                            &mut ws,
                            KernelKind::Fused,
                        )?;
                        std::hint::black_box(mfgs.len());
                    }
                }
                marks.push(comm.fenced_snapshot()?);
                let deltas: Vec<CommStats> =
                    marks.windows(2).map(|w| w[1].diff(&w[0])).collect();
                Ok((nb, deltas))
            },
        )?;
        let per_rank: Vec<(u64, Vec<CommStats>)> = collect_ranks(per_rank)?;
        let (nb, deltas) = &per_rank[0];
        ensure!(
            *nb > 0,
            "dataset {spec:?} too small for batch {batch} over {workers} workers"
        );
        let curve: Vec<(u64, u64)> = deltas
            .iter()
            .map(|s| (s.bytes_of(RoundKind::SampleRequest), s.rounds_of(RoundKind::SampleRequest)))
            .collect();
        for (e, &(bytes, rounds)) in curve.iter().enumerate() {
            out.push_str(&format!(
                "{:<18} {:>7} {:>16} ({rounds})\n",
                if e == 0 { label } else { "" },
                e,
                bytes
            ));
        }

        // The decay contract.
        if cache_bytes == 0 {
            ensure!(curve[0].0 > 0, "no cross-partition misses — workload too easy to measure");
            for w in curve.windows(2) {
                ensure!(
                    w[1].0 == w[0].0,
                    "{label}: identical epochs paid different request bytes ({} -> {})",
                    w[0].0,
                    w[1].0
                );
            }
        } else {
            for w in curve.windows(2) {
                ensure!(
                    w[1].0 <= w[0].0,
                    "{label}: request-byte curve not non-increasing ({} -> {})",
                    w[0].0,
                    w[1].0
                );
            }
        }
        if cache_bytes == unbounded {
            ensure!(
                curve[1..].iter().all(|&(b, r)| b == 0 && r == 0),
                "{label}: unbounded cache should clear every exchange after epoch 0 ({curve:?})"
            );
            ensure!(
                curve[0].0 > 0,
                "{label}: epoch 0 must pay the cold misses ({curve:?})"
            );
        }
    }
    out.push_str(
        "\ncontract held: cache off ⇒ flat curve; cache on ⇒ non-increasing request bytes; \
         unbounded cache ⇒ zero sampling traffic after epoch 0\n",
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 5 — fused-kernel speedup (single node).
// ---------------------------------------------------------------------------

pub struct Fig5Opts {
    pub dataset_spec: String,
    pub batch_sizes: Vec<usize>,
    pub fanout_sets: Vec<Vec<usize>>,
    pub iters: usize,
    /// Also measure the end-to-end panel (needs AOT variants).
    pub e2e: bool,
    pub seed: u64,
}

impl Default for Fig5Opts {
    fn default() -> Self {
        Self {
            dataset_spec: "papers100m-sim:0.005".into(),
            batch_sizes: vec![1024, 2048, 4096, 10240],
            fanout_sets: vec![vec![5, 5, 5], vec![10, 10, 10], vec![15, 10, 5], vec![20, 15, 10]],
            iters: 5,
            e2e: true,
            seed: 7,
        }
    }
}

/// Top panel of Fig 5: sampling-time speedup of fused vs DGL-style
/// baseline across batch sizes and fanouts (single node, full graph).
pub fn fig5_sampling(opts: &Fig5Opts) -> Result<String> {
    let d = config::dataset(&opts.dataset_spec, opts.seed)?;
    let key = RngKey::new(opts.seed);
    let mut out = String::new();
    out.push_str(&format!(
        "Fig 5 (top): sampling speedup, fused vs baseline — {} ({} nodes, {} edges)\n\n",
        d.name,
        d.num_nodes(),
        d.num_edges()
    ));
    out.push_str(&format!(
        "{:<16} {:>8} {:>14} {:>14} {:>9}\n",
        "fanouts", "batch", "baseline", "fused", "speedup"
    ));
    let mut ws = SamplerWorkspace::new();
    for fanouts in &opts.fanout_sets {
        for &b in &opts.batch_sizes {
            let schedule = MinibatchSchedule::new(&d.train_ids, b.min(d.train_ids.len()), key);
            if schedule.num_batches() == 0 {
                continue;
            }
            let seeds = schedule.batch(0);
            let time = |kind: KernelKind, ws: &mut SamplerWorkspace| {
                // Warm once, then time.
                let _ = sample_mfgs(&d.graph, seeds, fanouts, key, ws, kind);
                let t0 = Instant::now();
                for i in 0..opts.iters {
                    let k = key.fold(i as u64);
                    std::hint::black_box(sample_mfgs(&d.graph, seeds, fanouts, k, ws, kind));
                }
                t0.elapsed().as_secs_f64() / opts.iters as f64
            };
            let base = time(KernelKind::Baseline, &mut ws);
            let fused = time(KernelKind::Fused, &mut ws);
            out.push_str(&format!(
                "{:<16} {:>8} {:>13.2}ms {:>13.2}ms {:>8.2}x\n",
                format!("{fanouts:?}"),
                seeds.len(),
                base * 1e3,
                fused * 1e3,
                base / fused
            ));
        }
    }
    Ok(out)
}

/// Bottom panel of Fig 5: overall (sampling + training) single-node step
/// speedup, using the AOT variants compiled for the fig5 batch sizes.
pub fn fig5_e2e(opts: &Fig5Opts) -> Result<String> {
    let artifacts = config::artifacts_dir();
    let manifest = Manifest::load(&artifacts)?;
    let d = config::dataset(&opts.dataset_spec, opts.seed)?;
    let key = RngKey::new(opts.seed);
    let engine = Engine::cpu()?;
    let mut out = String::new();
    out.push_str(&format!(
        "Fig 5 (bottom): overall training-step speedup (sample + gather + train) — {}\n\n",
        d.name
    ));
    out.push_str(&format!(
        "{:<14} {:>8} {:>12} {:>12} {:>12} {:>9}\n",
        "variant", "batch", "sample", "train", "total", "speedup"
    ));
    let mut ws = SamplerWorkspace::new();
    let mut names: Vec<&String> = manifest.variants.keys().collect();
    names.sort();
    for name in names {
        if !name.starts_with("fig5_") {
            continue;
        }
        let rt = ModelRuntime::load(&engine, &manifest, name)?;
        let v = &rt.variant;
        if v.feat_dim != d.feat_dim {
            continue;
        }
        let schedule = MinibatchSchedule::new(&d.train_ids, v.batch, key);
        if schedule.num_batches() == 0 {
            out.push_str(&format!("{name:<14} SKIP (dataset too small for batch {})\n", v.batch));
            continue;
        }
        let seeds = schedule.batch(0);
        let params = rt.init_params(0);
        let mut feat_buf: Vec<f32> = Vec::new();
        let mut timings = Vec::new(); // (kind, sample_s, train_s)
        for kind in [KernelKind::Baseline, KernelKind::Fused] {
            let mut sample_s = 0.0;
            let mut train_s = 0.0;
            for i in 0..opts.iters.max(2) {
                let k = key.fold(i as u64);
                let t0 = Instant::now();
                let mfgs = sample_mfgs(&d.graph, seeds, &v.fanouts, k, &mut ws, kind);
                sample_s += t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                // Single-node: features come straight from local memory.
                let f = d.feat_dim;
                feat_buf.clear();
                for &n in &mfgs[0].src_nodes {
                    feat_buf.extend_from_slice(d.feat(n));
                }
                let _ = f;
                let padded = pad_batch(v, &mfgs, &feat_buf, |n| d.labels[n as usize])?;
                let step = rt.train_step(&params, &padded, i as i32)?;
                std::hint::black_box(step.loss);
                train_s += t1.elapsed().as_secs_f64();
            }
            let n = opts.iters.max(2) as f64;
            timings.push((kind, sample_s / n, train_s / n));
        }
        let (_, bs, bt) = timings[0];
        let (_, fs, ft) = timings[1];
        out.push_str(&format!(
            "{:<14} {:>8} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>8.3}x\n",
            name,
            v.batch,
            bs * 1e3,
            bt * 1e3,
            (bs + bt) * 1e3,
            (bs + bt) / (fs + ft)
        ));
        out.push_str(&format!(
            "{:<14} {:>8} {:>10.1}ms {:>10.1}ms {:>10.1}ms   (fused)\n",
            "", "", fs * 1e3, ft * 1e3, (fs + ft) * 1e3
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 6 — distributed epoch times.
// ---------------------------------------------------------------------------

pub struct Fig6Opts {
    /// (dataset spec, AOT variant) pairs.
    pub runs: Vec<(String, String)>,
    pub workers: Vec<usize>,
    pub modes: Vec<String>,
    pub epochs: usize,
    pub max_batches: Option<usize>,
    pub net: NetworkModel,
    pub seed: u64,
}

impl Default for Fig6Opts {
    fn default() -> Self {
        Self {
            runs: vec![
                ("products-sim:0.02".into(), "fig6_products_small".into()),
                ("papers100m-sim:0.002".into(), "fig6_papers_small".into()),
            ],
            workers: vec![4, 8],
            modes: vec![
                "vanilla".into(),
                "budget:64k".into(),
                "budget:256k".into(),
                "budget:1m".into(),
                "hybrid".into(),
                "hybrid+fused".into(),
            ],
            epochs: 2,
            max_batches: Some(8),
            net: NetworkModel::infiniband_200g(),
            seed: 11,
        }
    }
}

/// Paper Fig 6: distributed epoch time per mode × worker counts ×
/// datasets, with phase breakdown. Modes default to {vanilla, a
/// three-point replication-budget sweep (64k / 256k / 1m), hybrid,
/// hybrid+fused}; any `budget:<bytes>` / `halo:<hops>` mode string
/// works.
pub fn fig6(opts: &Fig6Opts) -> Result<String> {
    let artifacts = config::artifacts_dir();
    let mut out = String::new();
    out.push_str("Fig 6: distributed epoch times (mean over epochs; breakdown is per-worker mean)\n\n");
    out.push_str(&format!(
        "{:<26} {:>3}w {:<14} {:>10} {:>9} {:>9} {:>9} {:>9} {:>10} {:>9}\n",
        "dataset", "", "mode", "epoch", "sample", "feature", "compute", "sync", "non-comp", "vs vanilla"
    ));
    for (spec, variant) in &opts.runs {
        let d = config::dataset(spec, opts.seed)?;
        for &w in &opts.workers {
            let mut vanilla_time: Option<(f64, f64)> = None;
            for mode in &opts.modes {
                let mut cfg = TrainConfig::mode(variant, mode, w)?;
                cfg.epochs = opts.epochs;
                cfg.max_batches = opts.max_batches;
                cfg.net = opts.net.clone();
                cfg.seed = opts.seed;
                let report = train_distributed(&d, &artifacts, &cfg)?;
                let t = report.mean_epoch_wall_s();
                // "non-compute": sampling + feature exchange + grad sync —
                // the part of the epoch the paper's techniques act on.
                // (This testbed's 2 cores make GNN compute a far larger
                // fraction than on the paper's 2x56-core machines.)
                let times = &report.epochs.last().unwrap().times;
                let noncomp = t - times.compute_s;
                if mode == "vanilla" {
                    vanilla_time = Some((t, noncomp));
                }
                let speedup = vanilla_time.map(|(v, _)| v / t).unwrap_or(1.0);
                out.push_str(&format!(
                    "{:<26} {:>3}w {:<14} {:>9.2}s {:>8.2}s {:>8.2}s {:>8.2}s {:>8.2}s {:>9.2}s {:>8.2}x\n",
                    d.name, w, mode, t, times.sample_s, times.feature_s, times.compute_s,
                    times.sync_s, noncomp, speedup
                ));
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Ablations (DESIGN.md A1–A3).
// ---------------------------------------------------------------------------

/// A3: communication rounds + bytes per mode for one minibatch-sized run
/// — the 2L → 2 reduction, measured, plus budgeted points of the
/// replication spectrum in between. The counters tally frames actually
/// serialized for the configured transport, so running with
/// `--transport tcp` measures real wire payloads.
pub fn rounds_report(workers: usize, seed: u64, transport: &TransportConfig) -> Result<String> {
    let artifacts = config::artifacts_dir();
    let d = datasets::quickstart(seed);
    let mut out = String::new();
    out.push_str(&format!(
        "A3: communication rounds per training run (quickstart, {workers} workers, \
         {transport} transport, 2 epochs x 2 batches, L=3)\n\n"
    ));
    for mode in ["vanilla", "budget:16k", "halo:1", "hybrid", "hybrid+fused"] {
        let mut cfg = TrainConfig::mode("quickstart", mode, workers)?;
        cfg.epochs = 2;
        cfg.max_batches = Some(2);
        cfg.net = NetworkModel::free();
        cfg.seed = seed;
        cfg.transport = *transport;
        let report = train_distributed(&d, &artifacts, &cfg)?;
        let s = &report.comm_total;
        out.push_str(&format!("mode: {mode}\n{}\n", s.report()));
        let batches = report.epochs.iter().map(|e| e.batches as u64).sum::<u64>();
        let expect = match mode {
            "vanilla" => "2(L-1) = 4",
            "hybrid" | "hybrid+fused" => "0",
            "halo:1" => "2(L-2) = 2 — the 1-hop halo clears the first exchange",
            _ => "data-dependent, 0..=2(L-1)",
        };
        out.push_str(&format!(
            "sampling rounds/batch: {} (expected: {expect})\n\n",
            s.sampling_rounds() as f64 / batches as f64,
        ));
    }
    Ok(out)
}

/// A1: feature-cache ablation — remote feature bytes and epoch time vs
/// cache capacity (hybrid+fused).
pub fn cache_ablation(workers: usize, seed: u64) -> Result<String> {
    let artifacts = config::artifacts_dir();
    let d = datasets::quickstart(seed);
    let mut out = String::new();
    out.push_str(&format!(
        "A1: remote-feature cache ablation (quickstart, {workers} workers, hybrid+fused)\n\n{:<12} {:<8} {:>16} {:>12} {:>10}\n",
        "capacity", "policy", "feature bytes", "saved", "epoch"
    ));
    let mut base_bytes = None;
    for (cap, policy) in [
        (0usize, CachePolicy::StaticDegree),
        (200, CachePolicy::StaticDegree),
        (800, CachePolicy::StaticDegree),
        (200, CachePolicy::Clock),
        (800, CachePolicy::Clock),
    ] {
        let mut cfg = TrainConfig::mode("quickstart", "hybrid+fused", workers)?;
        cfg.epochs = 2;
        cfg.max_batches = Some(4);
        cfg.net = NetworkModel::free();
        cfg.seed = seed;
        cfg.cache_capacity = cap;
        cfg.cache_policy = policy;
        let report = train_distributed(&d, &artifacts, &cfg)?;
        let bytes = report.comm_total.bytes_of(RoundKind::FeatureResponse);
        if cap == 0 {
            base_bytes = Some(bytes);
        }
        let saved = base_bytes
            .map(|b| 100.0 * (1.0 - bytes as f64 / b as f64))
            .unwrap_or(0.0);
        out.push_str(&format!(
            "{:<12} {:<8} {:>16} {:>11.1}% {:>9.2}s\n",
            cap,
            format!("{policy:?}").chars().take(8).collect::<String>(),
            bytes,
            saved,
            report.mean_epoch_wall_s()
        ));
    }
    Ok(out)
}

/// A2: adaptive fanout ablation — fixed vs ramp vs plateau schedules:
/// per-epoch time and loss (paper §5 future work).
pub fn fanout_ablation(workers: usize, seed: u64) -> Result<String> {
    let artifacts = config::artifacts_dir();
    let d = datasets::quickstart(seed);
    let mut out = String::new();
    out.push_str(&format!(
        "A2: adaptive fanout schedules (quickstart, {workers} workers, hybrid+fused, 6 epochs)\n\n{:<22} {:>12} {:>12} {:>10}\n",
        "schedule", "total time", "final loss", "acc"
    ));
    for (name, schedule) in [
        ("fixed", ScheduleKind::Fixed),
        ("ramp(0.3, 4)", ScheduleKind::Ramp { start_frac: 0.3, ramp_epochs: 4 }),
        ("plateau(0.3,+0.35)", ScheduleKind::Plateau { start_frac: 0.3, step_frac: 0.35, tol: 0.01 }),
    ] {
        let mut cfg = TrainConfig::mode("quickstart", "hybrid+fused", workers)?;
        cfg.epochs = 6;
        cfg.max_batches = Some(4);
        cfg.net = NetworkModel::free();
        cfg.seed = seed;
        cfg.schedule = schedule;
        cfg.eval_last_batch = true;
        let report = train_distributed(&d, &artifacts, &cfg)?;
        let total: f64 = report.epochs.iter().map(|e| e.wall_s).sum();
        let last = report.epochs.last().unwrap();
        out.push_str(&format!(
            "{:<22} {:>11.2}s {:>12.4} {:>9.2}%\n",
            name,
            total,
            last.mean_loss,
            100.0 * last.acc.unwrap_or(0.0)
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Loss-curve run for EXPERIMENTS.md (E2E validation).
// ---------------------------------------------------------------------------

/// Train for real and dump the loss curve (the E2E deliverable's engine;
/// `examples/distributed_train.rs` wraps it).
pub fn e2e_run(
    dataset: &Dataset,
    variant: &str,
    mode: &str,
    workers: usize,
    epochs: usize,
    seed: u64,
) -> Result<String> {
    let artifacts = config::artifacts_dir();
    let mut cfg = TrainConfig::mode(variant, mode, workers)?;
    cfg.epochs = epochs;
    cfg.seed = seed;
    cfg.eval_last_batch = true;
    cfg.verbose = true;
    let report = train_distributed(dataset, &artifacts, &cfg)?;
    let mut out = String::new();
    out.push_str(&format!(
        "E2E: {} on {}, {workers} workers, mode {mode}, {epochs} epochs\n\n",
        variant, dataset.name
    ));
    out.push_str(&format!(
        "{:<7} {:>10} {:>10} {:>9} {:>9} {:>9} {:>9} {:>8}\n",
        "epoch", "loss", "epoch s", "sample", "feature", "compute", "sync", "acc"
    ));
    for e in &report.epochs {
        out.push_str(&format!(
            "{:<7} {:>10.4} {:>9.2}s {:>8.2}s {:>8.2}s {:>8.2}s {:>8.2}s {:>7.1}%\n",
            e.epoch,
            e.mean_loss,
            e.wall_s,
            e.times.sample_s,
            e.times.feature_s,
            e.times.compute_s,
            e.times.sync_s,
            100.0 * e.acc.unwrap_or(f32::NAN)
        ));
    }
    out.push_str("\nloss curve (worker 0, every step):\n");
    for (i, chunk) in report.loss_curve.chunks(10).enumerate() {
        let row: Vec<String> = chunk.iter().map(|l| format!("{l:.3}")).collect();
        out.push_str(&format!("  step {:>4}: {}\n", i * 10, row.join(" ")));
    }
    Ok(out)
}
