//! Shared run configuration helpers for the CLI, examples, and benches.

use std::path::PathBuf;

use anyhow::Result;

use crate::dist::NetworkModel;
use crate::graph::{datasets, Dataset};

/// Locate the AOT artifacts directory: `$FASTSAMPLE_ARTIFACTS` or
/// `<crate root>/artifacts` (built by `make artifacts`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("FASTSAMPLE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when artifacts exist (tests/examples skip politely otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Resolve a dataset spec (`name[:scale]`) with a fixed seed.
pub fn dataset(spec: &str, seed: u64) -> Result<Dataset> {
    datasets::by_name(spec, seed)
}

/// Resolve a network model by name: `infiniband` (paper fabric),
/// `ethernet`, `free` (accounting only).
pub fn network(name: &str) -> Result<NetworkModel> {
    match name {
        "infiniband" | "ib" => Ok(NetworkModel::infiniband_200g()),
        "ethernet" | "eth" => Ok(NetworkModel::ethernet_10g()),
        "free" | "none" => Ok(NetworkModel::free()),
        other => anyhow::bail!("unknown network model {other:?} (infiniband | ethernet | free)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn network_names_resolve() {
        assert!(network("infiniband").unwrap().inject_delay);
        assert!(!network("free").unwrap().inject_delay);
        assert!(network("warp").is_err());
    }

    #[test]
    fn artifacts_dir_points_into_crate_by_default() {
        // (Does not require artifacts to exist.)
        assert!(artifacts_dir().ends_with("artifacts"));
    }
}
