//! Shared run configuration helpers for the CLI, examples, and benches.

use std::path::PathBuf;

use anyhow::Result;

use crate::dist::NetworkModel;
use crate::graph::{datasets, Dataset};

/// Locate the AOT artifacts directory: `$FASTSAMPLE_ARTIFACTS` or
/// `<crate root>/artifacts` (built by `make artifacts`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("FASTSAMPLE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when artifacts exist (tests/examples skip politely otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Resolve a dataset spec (`name[:scale]`) with a fixed seed.
pub fn dataset(spec: &str, seed: u64) -> Result<Dataset> {
    datasets::by_name(spec, seed)
}

/// Parse a replication byte budget: `inf`/`unlimited`/`full` ⇒ `None`
/// (full replication, the hybrid arm), otherwise an integer byte count
/// with optional KiB-based `k`/`m`/`g` suffix (`0` ⇒ the vanilla arm).
pub fn parse_budget(spec: &str) -> Result<Option<u64>> {
    let s = spec.trim().to_ascii_lowercase();
    if matches!(s.as_str(), "inf" | "unlimited" | "full") {
        return Ok(None);
    }
    let (num, mult) = match s.as_bytes().last() {
        Some(b'k') => (&s[..s.len() - 1], 1u64 << 10),
        Some(b'm') => (&s[..s.len() - 1], 1u64 << 20),
        Some(b'g') => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s.as_str(), 1),
    };
    let n: u64 = num
        .parse()
        .map_err(|e| anyhow::anyhow!("bad replication budget {spec:?}: {e}"))?;
    Ok(Some(n.saturating_mul(mult)))
}

/// Parse a remote-adjacency cache budget (`cache:<bytes>` /
/// `--adj-cache`): same grammar as [`parse_budget`], with
/// `inf`/`unlimited`/`full` mapping to an effectively unbounded cache.
pub fn parse_cache_bytes(spec: &str) -> Result<u64> {
    Ok(parse_budget(spec)?.unwrap_or(u64::MAX >> 1))
}

/// Resolve a cache eviction policy by name: `clock` (second-chance,
/// the adaptive default) or `static` (first fill wins, never evict).
pub fn cache_policy(name: &str) -> Result<crate::dist::CachePolicy> {
    match name {
        "clock" => Ok(crate::dist::CachePolicy::Clock),
        "static" | "static-degree" => Ok(crate::dist::CachePolicy::StaticDegree),
        other => anyhow::bail!("unknown cache policy {other:?} (clock | static)"),
    }
}

/// Resolve a sampling wire format by name (`wire:<fmt>` mode suffix /
/// `--sampling-wire`): `bulk` (columnar counts + ids blob, the default)
/// or `scalar` (the run-length per-node stream). Content is
/// bit-identical either way; only the response encoding differs.
pub fn sampling_wire(name: &str) -> Result<crate::dist::SamplingWire> {
    match name {
        "bulk" => Ok(crate::dist::SamplingWire::Bulk),
        "scalar" => Ok(crate::dist::SamplingWire::Scalar),
        other => anyhow::bail!("unknown sampling wire {other:?} (scalar | bulk)"),
    }
}

/// Resolve a pipeline switch (`--pipeline on|off`, the `+pipe` mode
/// suffix's flag twin): `on` overlaps minibatch t+1's sampling + feature
/// fetch with minibatch t's compute + grad sync; `off` (default) runs
/// the phases serially. Results are bit-identical either way.
pub fn pipeline(spec: &str) -> Result<bool> {
    match spec {
        "on" => Ok(true),
        "off" => Ok(false),
        other => anyhow::bail!("unknown pipeline setting {other:?} (on | off)"),
    }
}

/// Resolve a transport spec: `inproc` (the in-process channel mesh,
/// default), `tcp` (per-peer loopback sockets, ephemeral ports), or
/// `tcp:<base_port>` (rank r binds `base_port + r`).
pub fn transport(spec: &str) -> Result<crate::dist::TransportConfig> {
    spec.parse().map_err(|e: String| anyhow::anyhow!(e))
}

/// Resolve a network model by name: `infiniband` (paper fabric),
/// `ethernet`, `free` (accounting only).
pub fn network(name: &str) -> Result<NetworkModel> {
    match name {
        "infiniband" | "ib" => Ok(NetworkModel::infiniband_200g()),
        "ethernet" | "eth" => Ok(NetworkModel::ethernet_10g()),
        "free" | "none" => Ok(NetworkModel::free()),
        other => anyhow::bail!("unknown network model {other:?} (infiniband | ethernet | free)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn network_names_resolve() {
        assert!(network("infiniband").unwrap().inject_delay);
        assert!(!network("free").unwrap().inject_delay);
        assert!(network("warp").is_err());
    }

    #[test]
    fn budgets_parse_across_the_spectrum() {
        assert_eq!(parse_budget("inf").unwrap(), None);
        assert_eq!(parse_budget("FULL").unwrap(), None);
        assert_eq!(parse_budget("0").unwrap(), Some(0));
        assert_eq!(parse_budget("4096").unwrap(), Some(4096));
        assert_eq!(parse_budget("64k").unwrap(), Some(64 << 10));
        assert_eq!(parse_budget("2m").unwrap(), Some(2 << 20));
        assert_eq!(parse_budget("1g").unwrap(), Some(1 << 30));
        assert!(parse_budget("lots").is_err());
        assert!(parse_budget("").is_err());
    }

    #[test]
    fn cache_specs_parse() {
        assert_eq!(parse_cache_bytes("0").unwrap(), 0);
        assert_eq!(parse_cache_bytes("32k").unwrap(), 32 << 10);
        assert_eq!(parse_cache_bytes("inf").unwrap(), u64::MAX >> 1);
        assert!(parse_cache_bytes("lots").is_err());
        assert_eq!(cache_policy("clock").unwrap(), crate::dist::CachePolicy::Clock);
        assert_eq!(
            cache_policy("static").unwrap(),
            crate::dist::CachePolicy::StaticDegree
        );
        assert!(cache_policy("lru").is_err());
    }

    #[test]
    fn sampling_wire_names_resolve() {
        use crate::dist::SamplingWire;
        assert_eq!(sampling_wire("bulk").unwrap(), SamplingWire::Bulk);
        assert_eq!(sampling_wire("scalar").unwrap(), SamplingWire::Scalar);
        assert_eq!(SamplingWire::default(), SamplingWire::Bulk);
        assert!(sampling_wire("columnar").is_err());
    }

    #[test]
    fn pipeline_settings_parse() {
        assert!(pipeline("on").unwrap());
        assert!(!pipeline("off").unwrap());
        assert!(pipeline("yes").is_err());
        assert!(pipeline("").is_err());
    }

    #[test]
    fn transport_specs_parse() {
        use crate::dist::TransportConfig;
        assert_eq!(transport("inproc").unwrap(), TransportConfig::Inproc);
        assert_eq!(transport("tcp").unwrap(), TransportConfig::Tcp { base_port: 0 });
        assert_eq!(transport("tcp:9200").unwrap(), TransportConfig::Tcp { base_port: 9200 });
        assert!(transport("quic").is_err());
    }

    #[test]
    fn artifacts_dir_points_into_crate_by_default() {
        // (Does not require artifacts to exist.)
        assert!(artifacts_dir().ends_with("artifacts"));
    }
}
