#!/usr/bin/env sh
# Launch an N-rank fastsample multi-process run on one host: N OS
# processes, one per rank, rendezvousing over real TCP. Extra arguments
# are passed through to every `fastsample worker` (e.g. --task sample
# --dataset quickstart --epochs 2). Rank 0 runs in the foreground (its
# stdout is yours); ranks 1..N-1 log to worker-<rank>.log in $PWD.
#
#   ./scripts/launch_workers.sh 4 127.0.0.1 9400 --task sample
#
# Exit status is non-zero if ANY rank fails. See OPERATIONS.md.
set -eu

WORLD=${1:?usage: launch_workers.sh <world> <host> <base_port> [worker flags...]}
HOST=${2:?usage: launch_workers.sh <world> <host> <base_port> [worker flags...]}
BASE=${3:?usage: launch_workers.sh <world> <host> <base_port> [worker flags...]}
shift 3

BIN=${FASTSAMPLE_BIN:-target/release/fastsample}

PEERS=""
i=0
while [ "$i" -lt "$WORLD" ]; do
    PEERS="$PEERS${PEERS:+,}$HOST:$((BASE + i))"
    i=$((i + 1))
done

PIDS=""
r=1
while [ "$r" -lt "$WORLD" ]; do
    "$BIN" worker --rank "$r" --peers "$PEERS" "$@" >"worker-$r.log" 2>&1 &
    PIDS="$PIDS $!"
    r=$((r + 1))
done

rc=0
"$BIN" worker --rank 0 --peers "$PEERS" "$@" || rc=$?
for p in $PIDS; do
    wait "$p" || rc=1
done
exit "$rc"
