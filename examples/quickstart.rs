//! Quickstart: the smallest end-to-end FastSample run.
//!
//! Generates a 2k-node planted-community graph, trains the AOT-compiled
//! 3-layer GraphSAGE for a few epochs on 2 workers with hybrid
//! partitioning + the fused sampling kernel, and prints the loss curve.
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use fastsample::config;
use fastsample::graph::datasets;
use fastsample::train::{train_distributed, TrainConfig};

fn main() -> anyhow::Result<()> {
    if !config::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    // 1. A small synthetic dataset (2k nodes, 8 classes, learnable).
    let dataset = datasets::quickstart(0);
    println!(
        "dataset: {} — {} nodes, {} edges, {} classes, {} labeled",
        dataset.name,
        dataset.num_nodes(),
        dataset.num_edges(),
        dataset.num_classes,
        dataset.train_ids.len()
    );

    // 2. Configure: 2 workers, hybrid partitioning + fused kernel.
    let mut cfg = TrainConfig::mode("quickstart", "hybrid+fused", 2)?;
    cfg.epochs = 5;
    cfg.eval_last_batch = true;
    cfg.verbose = true;

    // 3. Train (each worker compiles the AOT artifacts, samples locally,
    //    exchanges features, runs the PJRT train step, all-reduces grads).
    let report = train_distributed(&dataset, &config::artifacts_dir(), &cfg)?;

    // 4. Results.
    println!("\nepoch  loss     acc");
    for e in &report.epochs {
        println!(
            "{:>5}  {:.4}  {:>5.1}%",
            e.epoch,
            e.mean_loss,
            100.0 * e.acc.unwrap_or(f32::NAN)
        );
    }
    let first = report.epochs.first().unwrap().mean_loss;
    let last = report.epochs.last().unwrap().mean_loss;
    println!("\nloss {first:.3} -> {last:.3} over {} epochs", cfg.epochs);
    println!("sampling comm rounds: {} (hybrid ⇒ 0)", report.comm_total.sampling_rounds());
    Ok(())
}
