//! The paper's core kernel claim, demonstrated: the fused CSC-direct
//! sampler (Algorithm 1) returns **exactly** the same sampled graphs as
//! the DGL-style two-step pipeline, while doing strictly less memory
//! movement — then measures both across fanouts.
//!
//! Run:  cargo run --release --example sampling_comparison
//! Flags: --scale 0.002 --batch 1024 --iters 10

use fastsample::config;
use fastsample::sampling::rng::RngKey;
use fastsample::sampling::{sample_mfgs, KernelKind, MinibatchSchedule, SamplerWorkspace};
use fastsample::util::bench::{header, Bencher};
use fastsample::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let scale = args.get("scale", 0.002f64)?;
    let batch = args.get("batch", 1024usize)?;
    let iters = args.get("iters", 10usize)?;
    args.finish()?;

    let d = config::dataset(&format!("papers100m-sim:{scale}"), 1)?;
    println!(
        "graph: {} — {} nodes, {} edges (max degree {})\n",
        d.name,
        d.num_nodes(),
        d.num_edges(),
        d.graph.max_degree()
    );

    let key = RngKey::new(42);
    let schedule = MinibatchSchedule::new(&d.train_ids, batch.min(d.train_ids.len()), key);
    let seeds = schedule.batch(0);
    let mut ws_a = SamplerWorkspace::new();
    let mut ws_b = SamplerWorkspace::new();

    // ---- 1. Equivalence: bit-identical MFGs on every level.
    for fanouts in [vec![15usize, 10, 5], vec![10, 10, 10], vec![5, 5, 5]] {
        let a = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws_a, KernelKind::Fused);
        let b = sample_mfgs(&d.graph, seeds, &fanouts, key, &mut ws_b, KernelKind::Baseline);
        assert_eq!(a, b, "kernels disagree at fanouts {fanouts:?}");
        let edges: usize = a.iter().map(|m| m.num_edges()).sum();
        let nodes = a[0].num_src();
        println!(
            "fanouts {fanouts:?}: identical MFGs ✓ ({} seeds → {} input nodes, {} edges)",
            seeds.len(),
            nodes,
            edges
        );
    }

    // ---- 2. Speed: mean per-minibatch sampling time.
    println!("\n{}", header());
    let bench = Bencher {
        budget: std::time::Duration::from_secs(2),
        min_iters: iters,
        ..Default::default()
    };
    for fanouts in [vec![15usize, 10, 5], vec![10, 10, 10], vec![20, 15, 10]] {
        for kind in [KernelKind::Baseline, KernelKind::Fused] {
            let mut ws = SamplerWorkspace::new();
            let mut i = 0u64;
            let stats = bench.run(&format!("{kind:?} {fanouts:?}"), || {
                i += 1;
                sample_mfgs(&d.graph, seeds, &fanouts, key.fold(i), &mut ws, kind)
            });
            println!("{}", stats.row());
        }
    }
    Ok(())
}
