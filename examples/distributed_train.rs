//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Trains the paper's model (3-layer GraphSAGE, hidden 256) on a
//! products-sim graph across 4 workers with hybrid partitioning + the
//! fused sampling kernel, for a few hundred steps, and logs the loss
//! curve plus the per-phase time breakdown — proving that all three
//! layers (rust coordinator → PJRT executable → Pallas aggregation
//! kernel) compose on a real workload.
//!
//! Run:  make artifacts && cargo run --release --example distributed_train
//! Flags: --scale 0.01 --workers 4 --epochs 4 --mode hybrid+fused

use fastsample::config;
use fastsample::coordinator::experiments;
use fastsample::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let scale = args.get("scale", 0.01f64)?;
    let workers = args.get("workers", 4usize)?;
    let epochs = args.get("epochs", 4usize)?;
    let mode = args.get_str("mode", "hybrid+fused");
    let seed = args.get("seed", 0u64)?;
    args.finish()?;

    if !config::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    // products-sim with the real graph's feature/class dims (100 / 47).
    // Default scale 0.01 → 25k nodes, ~1.7M edges; batch 128/worker.
    let dataset = config::dataset(&format!("products-sim:{scale}"), seed)?;
    println!(
        "E2E driver: {} — {} nodes, {} edges, {} labeled; {} workers, mode {}",
        dataset.name,
        dataset.num_nodes(),
        dataset.num_edges(),
        dataset.train_ids.len(),
        workers,
        mode
    );

    let report =
        experiments::e2e_run(&dataset, "e2e_products", &mode, workers, epochs, seed)?;
    println!("{report}");
    Ok(())
}
