//! The billion-scale scenario, scaled: partition a papers100M-like graph
//! across 8 workers, compare vanilla vs hybrid partitioning end to end —
//! memory per worker, communication rounds/bytes, and epoch time — the
//! trade the paper's §3.3/§5 argues for.
//!
//! Run:  make artifacts && cargo run --release --example papers100m_sim
//! Flags: --scale 0.002 --workers 8 --batches 4

use fastsample::config;
use fastsample::dist::RoundKind;
use fastsample::partition::{build_shards, partition_graph, PartitionConfig, Scheme};
use fastsample::train::{train_distributed, TrainConfig};
use fastsample::util::cli::Args;
use std::sync::Arc;

fn human(b: u64) -> String {
    format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let scale = args.get("scale", 0.002f64)?;
    let workers = args.get("workers", 8usize)?;
    let batches = args.get("batches", 4usize)?;
    args.finish()?;

    if !config::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let d = config::dataset(&format!("papers100m-sim:{scale}"), 3)?;
    println!(
        "{} — {} nodes, {} edges, feat dim {}, {} classes\n",
        d.name,
        d.num_nodes(),
        d.num_edges(),
        d.feat_dim,
        d.num_classes
    );

    // ---- Per-worker memory: the "acceptable compromise" (Fig 4 logic).
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(workers)));
    println!("partition: edge cut {:.3}", book.cut_fraction(&d.graph));
    println!("\nper-worker memory            topology      features");
    for (name, scheme) in [("vanilla", Scheme::Vanilla), ("hybrid", Scheme::Hybrid)] {
        let shards = build_shards(&d, &book, scheme);
        let topo = shards.iter().map(|s| s.topology.storage_bytes() as u64).max().unwrap();
        let feat = shards.iter().map(|s| s.feature_bytes() as u64).max().unwrap();
        println!("  {name:<24} {:>12} {:>12}", human(topo), human(feat));
    }

    // ---- End to end: same training, different communication structure.
    println!("\nmode            epoch s   sampling rounds   feature bytes    total bytes");
    for mode in ["vanilla", "hybrid", "hybrid+fused"] {
        let mut cfg = TrainConfig::mode("fig6_papers", mode, workers)?;
        cfg.epochs = 1;
        cfg.max_batches = Some(batches);
        let r = train_distributed(&d, &config::artifacts_dir(), &cfg)?;
        println!(
            "{:<14} {:>8.2}s {:>17} {:>15} {:>14}",
            mode,
            r.mean_epoch_wall_s(),
            r.comm_total.sampling_rounds(),
            r.comm_total.bytes_of(RoundKind::FeatureResponse),
            r.comm_total.total_bytes()
        );
    }
    println!("\n(hybrid: sampling rounds drop from 2(L-1)/batch to 0 — paper §3.3)");
    Ok(())
}
