//! The billion-scale scenario, scaled: partition a papers100M-like graph
//! across 8 workers and sweep the replication spectrum (vanilla → halo
//! budget → hybrid) end to end — memory per worker, communication
//! rounds/bytes, and epoch time — the trade the paper's §3.3/§5 argues
//! for, as a dial.
//!
//! Run:  make artifacts && cargo run --release --example papers100m_sim
//! Flags: --scale 0.002 --workers 8 --batches 4

use fastsample::config;
use fastsample::dist::RoundKind;
use fastsample::partition::{build_shards, partition_graph, PartitionConfig, ReplicationPolicy};
use fastsample::train::{train_distributed, TrainConfig};
use fastsample::util::cli::Args;
use std::sync::Arc;

fn human(b: u64) -> String {
    format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let scale = args.get("scale", 0.002f64)?;
    let workers = args.get("workers", 8usize)?;
    let batches = args.get("batches", 4usize)?;
    args.finish()?;

    if !config::artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let d = config::dataset(&format!("papers100m-sim:{scale}"), 3)?;
    println!(
        "{} — {} nodes, {} edges, feat dim {}, {} classes\n",
        d.name,
        d.num_nodes(),
        d.num_edges(),
        d.feat_dim,
        d.num_classes
    );

    // ---- Per-worker memory: the replication spectrum, not a binary
    // (budget anchored on the measured 1-hop halo).
    let book = Arc::new(partition_graph(&d.graph, &d.train_ids, &PartitionConfig::new(workers)));
    println!("partition: edge cut {:.3}", book.cut_fraction(&d.graph));
    let halo = book.halo_profile(&d.graph);
    let max_halo = halo.iter().map(|h| h.halo_bytes).max().unwrap_or(0).max(64);
    println!("1-hop halo: up to {} per worker", human(max_halo));
    println!("\nper-worker memory            topology    replicated      features");
    for policy in [
        ReplicationPolicy::vanilla(),
        ReplicationPolicy::budgeted(max_halo / 2),
        ReplicationPolicy::hybrid(),
    ] {
        let shards = build_shards(&d, &book, &policy);
        let topo = shards.iter().map(|s| s.topology.storage_bytes() as u64).max().unwrap();
        let repl = shards.iter().map(|s| s.topology.replicated_bytes()).max().unwrap();
        let feat = shards.iter().map(|s| s.feature_bytes() as u64).max().unwrap();
        println!("  {:<24} {:>12} {:>12} {:>12}", policy.label(), human(topo), human(repl), human(feat));
    }

    // ---- End to end: same training, different communication structure.
    println!("\nmode            epoch s   sampling rounds   feature bytes    total bytes");
    let budget_mode = format!("budget:{}", max_halo / 2);
    for mode in ["vanilla", budget_mode.as_str(), "hybrid", "hybrid+fused"] {
        let mut cfg = TrainConfig::mode("fig6_papers", mode, workers)?;
        cfg.epochs = 1;
        cfg.max_batches = Some(batches);
        let r = train_distributed(&d, &config::artifacts_dir(), &cfg)?;
        println!(
            "{:<14} {:>8.2}s {:>17} {:>15} {:>14}",
            mode,
            r.mean_epoch_wall_s(),
            r.comm_total.sampling_rounds(),
            r.comm_total.bytes_of(RoundKind::FeatureResponse),
            r.comm_total.total_bytes()
        );
    }
    println!("\n(sampling rounds fall with the replication budget: 2(L-1)/batch at budget 0,\n 0 at full replication — paper §3.3, generalized)");
    Ok(())
}
