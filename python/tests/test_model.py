"""L2 correctness: GraphSAGE model over padded MFGs.

The only non-jnp piece of the model is the Pallas aggregation (tested
against its oracle in test_kernel.py); here we test the model-level
contracts the rust coordinator relies on: shapes, argument order, padding
inertness, gradient correctness vs an oracle-built twin model, and that the
train step actually learns a small planted task.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import mean_aggregate_ref
from compile import model as M


def tiny_cfg(**kw):
    d = dict(feat_dim=8, hidden=16, classes=4, batch=8, fanouts=(2, 2), dropout=0.0)
    d.update(kw)
    caps = M.compute_caps(d["batch"], d["fanouts"])
    return M.ModelConfig(caps=caps, **d)


def random_inputs(cfg, rng, n_real=None):
    """Build a fully-padded random MFG stack consistent with the convention:
    dst nodes are a prefix of the level-below node array."""
    caps = cfg.caps
    L = cfg.layers
    feats = jnp.asarray(rng.normal(size=(caps[0], cfg.feat_dim)), jnp.float32)
    mfgs = []
    for l in range(1, L + 1):
        k = cfg.fanouts[L - l]
        n_dst, n_src = caps[l], caps[l - 1]
        idx = jnp.asarray(rng.integers(0, n_src, (n_dst, k)), jnp.int32)
        cnt = jnp.asarray(rng.integers(0, k + 1, n_dst), jnp.int32)
        mfgs.append((idx, cnt))
    labels = jnp.asarray(rng.integers(0, cfg.classes, cfg.batch), jnp.int32)
    mask = jnp.ones(cfg.batch, jnp.float32)
    return feats, mfgs, labels, mask


def ref_forward(cfg, params, feats, mfgs):
    """Twin of M.forward built on the pure-jnp oracle aggregation."""
    h = feats
    for l in range(1, cfg.layers + 1):
        idx, cnt = mfgs[l - 1]
        w_self, w_neigh, bias = params[3 * (l - 1) : 3 * l]
        agg = mean_aggregate_ref(h, idx, cnt)
        h = h[: cfg.caps[l]] @ w_self + agg @ w_neigh + bias
        if l < cfg.layers:
            h = jax.nn.relu(h)
    return h


def test_forward_shape_and_matches_oracle_twin():
    cfg = tiny_cfg()
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    feats, mfgs, _, _ = random_inputs(cfg, rng)
    out = M.forward(cfg, params, feats, mfgs, train=False)
    ref = ref_forward(cfg, params, feats, mfgs)
    assert out.shape == (cfg.batch, cfg.classes)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_grads_match_oracle_twin():
    cfg = tiny_cfg()
    rng = np.random.default_rng(1)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    feats, mfgs, labels, mask = random_inputs(cfg, rng)

    def loss_k(p):
        return M.masked_cross_entropy(M.forward(cfg, p, feats, mfgs, train=False), labels, mask)

    def loss_r(p):
        return M.masked_cross_entropy(ref_forward(cfg, p, feats, mfgs), labels, mask)

    gk = jax.grad(loss_k)(params)
    gr = jax.grad(loss_r)(params)
    for a, b, (name, _) in zip(gk, gr, M.param_spec(cfg)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4, err_msg=name)


def test_train_step_flat_signature_and_grad_shapes():
    cfg = tiny_cfg()
    rng = np.random.default_rng(2)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    feats, mfgs, labels, mask = random_inputs(cfg, rng)
    args = list(params) + [feats]
    for idx, cnt in mfgs:
        args += [idx, cnt]
    args += [labels, mask, jnp.int32(0)]
    out = M.make_train_step(cfg)(*args)
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
    assert np.isfinite(float(out[0]))


def test_eval_step_no_dropout_is_deterministic():
    cfg = tiny_cfg(dropout=0.5)
    rng = np.random.default_rng(3)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    feats, mfgs, _, _ = random_inputs(cfg, rng)
    args = list(params) + [feats]
    for idx, cnt in mfgs:
        args += [idx, cnt]
    step = M.make_eval_step(cfg)
    (a,) = step(*args)
    (b,) = step(*args)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dropout_changes_with_seed_but_not_loss_scale():
    cfg = tiny_cfg(dropout=0.5)
    rng = np.random.default_rng(4)
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    feats, mfgs, _, _ = random_inputs(cfg, rng)
    a = M.forward(cfg, params, feats, mfgs, train=True, seed=jnp.int32(1))
    b = M.forward(cfg, params, feats, mfgs, train=True, seed=jnp.int32(2))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_masked_cross_entropy_ignores_masked_seeds():
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 4, 6), jnp.int32)
    mask = jnp.asarray([1, 1, 1, 0, 0, 0], jnp.float32)
    base = M.masked_cross_entropy(logits, labels, mask)
    # Perturb masked rows arbitrarily: loss unchanged.
    logits2 = logits.at[3:].set(1e3)
    np.testing.assert_allclose(base, M.masked_cross_entropy(logits2, labels, mask), atol=1e-6)


def test_masked_cross_entropy_all_masked_is_finite():
    logits = jnp.zeros((4, 3), jnp.float32)
    labels = jnp.zeros(4, jnp.int32)
    mask = jnp.zeros(4, jnp.float32)
    assert np.isfinite(float(M.masked_cross_entropy(logits, labels, mask)))


def test_padding_nodes_are_inert():
    """A batch where only the first half of the seeds is real must produce
    the same loss as the unpadded computation on that half."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(6)
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    feats, mfgs, labels, _ = random_inputs(cfg, rng)
    half = cfg.batch // 2
    mask = jnp.asarray([1.0] * half + [0.0] * (cfg.batch - half), jnp.float32)

    # Zero out everything belonging to padded seeds: their neighbor counts.
    mfgs_scrambled = []
    for li, (idx, cnt) in enumerate(mfgs):
        if li == cfg.layers - 1:  # top layer rows beyond `half` are padding
            cnt = cnt.at[half:].set(0)
            idx2 = idx.at[half:].set(0)
            mfgs_scrambled.append((idx2, cnt))
        else:
            mfgs_scrambled.append((idx, cnt))

    l1 = M.masked_cross_entropy(
        M.forward(cfg, params, feats, mfgs_scrambled, train=False), labels, mask
    )
    # Scramble padded-seed neighbor slots: must not change the masked loss.
    idx, cnt = mfgs_scrambled[-1]
    idx3 = idx.at[half:].set(jnp.asarray(rng.integers(0, cfg.caps[cfg.layers - 1]), jnp.int32))
    l2 = M.masked_cross_entropy(
        M.forward(cfg, params, feats, mfgs_scrambled[:-1] + [(idx3, cnt)], train=False),
        labels,
        mask,
    )
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_compute_caps():
    assert M.compute_caps(32, (3, 3, 3)) == (2048, 512, 128, 32)
    assert M.compute_caps(10, (2,)) == (30, 10)
    assert M.compute_caps(1000, (15, 10, 5), node_limit=5000) == (5000, 5000, 5000, 1000)


def test_arg_order_counts():
    cfg = tiny_cfg()
    names_t = M.arg_order(cfg, for_train=True)
    names_e = M.arg_order(cfg, for_train=False)
    assert names_t[-3:] == ["labels", "label_mask", "seed"]
    assert len(names_t) == len(M.example_args(cfg, for_train=True))
    assert len(names_e) == len(M.example_args(cfg, for_train=False))


def test_sgd_learns_planted_task():
    """A few dozen SGD steps on a separable planted task must cut the loss
    well below chance — the end-to-end learnability signal for L2."""
    cfg = tiny_cfg(feat_dim=8, hidden=16, classes=4, batch=16, fanouts=(2, 2))
    rng = np.random.default_rng(7)
    params = M.init_params(cfg, jax.random.PRNGKey(7))

    # Planted task: features of a node = one-hot-ish centroid of its class.
    centroids = np.eye(4).repeat(2, axis=1)  # [4, 8]

    def make_batch():
        feats_lbl = rng.integers(0, 4, cfg.caps[0])
        feats = centroids[feats_lbl] + 0.05 * rng.normal(size=(cfg.caps[0], 8))
        mfgs = []
        for l in range(1, cfg.layers + 1):
            k = cfg.fanouts[cfg.layers - l]
            n_dst, n_src = cfg.caps[l], cfg.caps[l - 1]
            # Neighbors of node i point to same-class nodes at the level
            # below (class is propagated by the dst-prefix convention).
            idx = np.zeros((n_dst, k), np.int64)
            for i in range(n_dst):
                same = np.flatnonzero(feats_lbl[:n_src] == feats_lbl[i])
                idx[i] = rng.choice(same, k)
            mfgs.append((jnp.asarray(idx, jnp.int32), jnp.full(n_dst, k, jnp.int32)))
        labels = jnp.asarray(feats_lbl[: cfg.batch], jnp.int32)
        return jnp.asarray(feats, jnp.float32), mfgs, labels

    step = jax.jit(M.make_train_step(cfg))
    mask = jnp.ones(cfg.batch, jnp.float32)
    losses = []
    for i in range(40):
        feats, mfgs, labels = make_batch()
        args = list(params) + [feats]
        for idx, cnt in mfgs:
            args += [idx, cnt]
        args += [labels, mask, jnp.int32(i)]
        out = step(*args)
        losses.append(float(out[0]))
        params = tuple(p - 0.5 * g for p, g in zip(params, out[1:]))
    assert losses[-1] < 0.4 * losses[0], losses
