"""AOT pipeline tests: HLO text round-trip and manifest contract.

Verifies the exact interchange the rust runtime depends on: HLO text parses
back into an XlaComputation, executing the lowered train step via jax equals
calling the python function directly, and the manifest records the argument
order / caps the rust side uses to build literals.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def small_cfg():
    return aot._variant(8, 16, 4, 8, (2, 2), dropout=0.0)


def flat_args(cfg, rng, train=True):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    args = list(params)
    args.append(jnp.asarray(rng.normal(size=(cfg.caps[0], cfg.feat_dim)), jnp.float32))
    for l in range(1, cfg.layers + 1):
        k = cfg.fanouts[cfg.layers - l]
        args.append(jnp.asarray(rng.integers(0, cfg.caps[l - 1], (cfg.caps[l], k)), jnp.int32))
        args.append(jnp.asarray(rng.integers(0, k + 1, cfg.caps[l]), jnp.int32))
    if train:
        args.append(jnp.asarray(rng.integers(0, cfg.classes, cfg.batch), jnp.int32))
        args.append(jnp.ones(cfg.batch, jnp.float32))
        args.append(jnp.int32(0))
    return args


def test_hlo_text_well_formed_and_aot_executes():
    """HLO text is parseable-looking; the AOT-compiled executable (same
    lowering the text is produced from) equals the direct python call.
    The text→rust round-trip itself is covered by `cargo test` (runtime)."""
    cfg = small_cfg()
    step = M.make_train_step(cfg)
    lowered = jax.jit(step).lower(*M.example_args(cfg, for_train=True))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # All inputs appear as parameters (flat, non-tupled signature).
    assert text.count("parameter(") >= len(M.example_args(cfg, for_train=True))

    args = flat_args(cfg, np.random.default_rng(0))
    expect = step(*args)
    compiled = lowered.compile()
    got = compiled(*args)
    np.testing.assert_allclose(float(got[0]), float(expect[0]), atol=1e-5)
    for o, e in zip(got[1:], expect[1:]):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e), atol=1e-5)


def test_lower_variant_writes_files_and_manifest_entry():
    cfg = small_cfg()
    with tempfile.TemporaryDirectory() as d:
        entry = aot.lower_variant("t", cfg, d)
        assert os.path.exists(os.path.join(d, "t_train.hlo.txt"))
        assert os.path.exists(os.path.join(d, "t_eval.hlo.txt"))
        assert entry["caps"] == list(cfg.caps)
        assert entry["train_args"][-3:] == ["labels", "label_mask", "seed"]
        assert len(entry["params"]) == 3 * cfg.layers
        # Param spec names/shapes must match the model's contract.
        for p, (name, shape) in zip(entry["params"], M.param_spec(cfg)):
            assert p["name"] == name and tuple(p["shape"]) == shape


def test_registered_variants_have_consistent_caps():
    for name, cfg in aot.VARIANTS.items():
        assert cfg.caps[len(cfg.fanouts)] == cfg.batch, name
        for l in range(len(cfg.fanouts), 0, -1):
            f = cfg.fanouts[len(cfg.fanouts) - l]
            assert cfg.caps[l - 1] <= cfg.caps[l] * (1 + f), name


def test_manifest_json_round_trip():
    cfg = small_cfg()
    with tempfile.TemporaryDirectory() as d:
        entry = aot.lower_variant("t", cfg, d)
        path = os.path.join(d, "manifest.json")
        with open(path, "w") as f:
            json.dump({"variants": {"t": entry}}, f)
        with open(path) as f:
            back = json.load(f)
        assert back["variants"]["t"]["fanouts"] == list(cfg.fanouts)
