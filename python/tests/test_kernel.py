"""L1 correctness: Pallas aggregation kernels vs the pure-jnp oracle.

hypothesis sweeps shapes/dtypes/degree distributions; explicit cases pin the
edge geometry (empty neighborhoods, single row, non-divisible tiles).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import mean_aggregate_grad_ref, mean_aggregate_ref
from compile.kernels.sage_agg import (
    mean_aggregate,
    mean_aggregate_bwd,
    mean_aggregate_fwd,
)


def _case(rng, n_src, n_dst, k, f, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(n_src, f)), dtype)
    idx = jnp.asarray(rng.integers(0, n_src, (n_dst, k)), jnp.int32)
    cnt = jnp.asarray(rng.integers(0, k + 1, n_dst), jnp.int32)
    return x, idx, cnt


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=1e-5, rtol=1e-5)


shapes = st.tuples(
    st.integers(1, 300),  # n_src
    st.integers(1, 200),  # n_dst
    st.integers(1, 12),  # K
    st.integers(1, 160),  # F
)


@settings(max_examples=40, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1))
def test_fwd_matches_ref(shapes, seed):
    n_src, n_dst, k, f = shapes
    x, idx, cnt = _case(np.random.default_rng(seed), n_src, n_dst, k, f)
    out = mean_aggregate_fwd(x, idx, cnt)
    ref = mean_aggregate_ref(x, idx, cnt)
    assert out.shape == (n_dst, f)
    np.testing.assert_allclose(out, ref, **_tol(jnp.float32))


@settings(max_examples=20, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1))
def test_bwd_matches_ref(shapes, seed):
    n_src, n_dst, k, f = shapes
    rng = np.random.default_rng(seed)
    _, idx, cnt = _case(rng, n_src, n_dst, k, f)
    g = jnp.asarray(rng.normal(size=(n_dst, f)), jnp.float32)
    out = mean_aggregate_bwd(g, idx, cnt, n_src)
    ref = mean_aggregate_grad_ref(g, idx, cnt, n_src)
    assert out.shape == (n_src, f)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(shapes=shapes, seed=st.integers(0, 2**31 - 1))
def test_custom_vjp_matches_jax_grad_of_ref(shapes, seed):
    n_src, n_dst, k, f = shapes
    x, idx, cnt = _case(np.random.default_rng(seed), n_src, n_dst, k, f)
    w = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(n_dst, f)), jnp.float32)

    gk = jax.grad(lambda x: (mean_aggregate(x, idx, cnt) * w).sum())(x)
    gr = jax.grad(lambda x: (mean_aggregate_ref(x, idx, cnt) * w).sum())(x)
    np.testing.assert_allclose(gk, gr, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    x, idx, cnt = _case(np.random.default_rng(0), 64, 48, 5, 32, dtype)
    out = mean_aggregate_fwd(x, idx, cnt)
    ref = mean_aggregate_ref(x, idx, cnt)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_zero_count_rows_are_zero():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(10, 7)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 10, (5, 4)), jnp.int32)
    cnt = jnp.zeros(5, jnp.int32)
    out = mean_aggregate_fwd(x, idx, cnt)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((5, 7), np.float32))


def test_full_count_is_plain_mean():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(20, 9)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 20, (8, 6)), jnp.int32)
    cnt = jnp.full(8, 6, jnp.int32)
    out = mean_aggregate_fwd(x, idx, cnt)
    np.testing.assert_allclose(out, np.asarray(x)[np.asarray(idx)].mean(1), atol=1e-5)


def test_padding_slots_do_not_leak():
    """Whatever sits in idx slots past cnt must not affect the output."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(30, 5)), jnp.float32)
    idx_a = jnp.asarray(rng.integers(0, 30, (6, 4)), jnp.int32)
    cnt = jnp.asarray([0, 1, 2, 3, 4, 2], jnp.int32)
    # Scramble only the invalid slots.
    idx_b = np.asarray(idx_a).copy()
    for i, c in enumerate(np.asarray(cnt)):
        idx_b[i, c:] = rng.integers(0, 30, 4 - c)
    out_a = mean_aggregate_fwd(x, idx_a, cnt)
    out_b = mean_aggregate_fwd(x, jnp.asarray(idx_b), cnt)
    np.testing.assert_allclose(out_a, out_b, atol=1e-6)


def test_single_element_shapes():
    x, idx, cnt = _case(np.random.default_rng(4), 1, 1, 1, 1)
    out = mean_aggregate_fwd(x, idx, cnt)
    ref = mean_aggregate_ref(x, idx, cnt)
    np.testing.assert_allclose(out, ref, atol=1e-6)


def test_non_divisible_tiles():
    """Shapes deliberately coprime with the 128-wide default blocks."""
    x, idx, cnt = _case(np.random.default_rng(5), 257, 131, 7, 129)
    out = mean_aggregate_fwd(x, idx, cnt)
    ref = mean_aggregate_ref(x, idx, cnt)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_under_jit():
    x, idx, cnt = _case(np.random.default_rng(6), 100, 70, 5, 33)
    f = jax.jit(lambda x, i, c: mean_aggregate_fwd(x, i, c))
    np.testing.assert_allclose(f(x, idx, cnt), mean_aggregate_ref(x, idx, cnt), atol=1e-5)


def test_grad_under_jit():
    x, idx, cnt = _case(np.random.default_rng(7), 90, 40, 6, 21)
    g = jax.jit(jax.grad(lambda x: mean_aggregate(x, idx, cnt).sum()))(x)
    gr = jax.grad(lambda x: mean_aggregate_ref(x, idx, cnt).sum())(x)
    np.testing.assert_allclose(g, gr, atol=1e-4)


def test_duplicate_neighbor_indices_accumulate():
    """Repeated idx entries contribute multiple times (with-replacement)."""
    x = jnp.asarray(np.eye(4, dtype=np.float32))
    idx = jnp.asarray([[2, 2, 2]], jnp.int32)
    cnt = jnp.asarray([3], jnp.int32)
    out = mean_aggregate_fwd(x, idx, cnt)
    np.testing.assert_allclose(out, np.eye(4, dtype=np.float32)[2][None], atol=1e-6)
