"""L1 Pallas kernels for the FastSample GNN compute hot-spot."""

from compile.kernels.sage_agg import (  # noqa: F401
    mean_aggregate,
    mean_aggregate_bwd,
    mean_aggregate_fwd,
)
