"""L1 Pallas kernels: fused gather + masked-mean neighbor aggregation.

This is the GNN compute hot-spot of the FastSample stack (the paper's own
hot-spot, the *sampling* kernel, is a CPU kernel and lives in the rust L3
coordinator — see DESIGN.md §Hardware-Adaptation).

Forward:  out[i] = mean_{k < counts[i]} features[idx[i, k]]       (0 if count==0)
Backward: d_features = scatter_add(idx[i, k] += g[i] / counts[i])  (masked)

Both directions are Pallas kernels (interpret=True — CPU PJRT cannot run
Mosaic custom-calls). TPU tiling strategy: the grid is
(n_dst / block_n, F / block_f); each program keeps a `[block_n, K]` index
tile, a `[block_n, block_f]` accumulator, and the gathered rows in VMEM, so
HBM→VMEM traffic is O(touched rows) per block. `block_f` defaults to 128 to
line up with MXU/VPU lane width.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the TPU lane width; block_n trades VMEM
# for grid parallelism.
BLOCK_N = 128
BLOCK_F = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fwd_kernel(x_ref, idx_ref, cnt_ref, o_ref):
    """One (dst-block, feature-block) tile of the masked-mean aggregation."""
    idx = idx_ref[...]  # [bn, K] int32
    cnt = cnt_ref[...]  # [bn]    int32
    rows = x_ref[idx]  # gather: [bn, K, bf]
    bn, k = idx.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1)
    mask = (lane < cnt[:, None]).astype(rows.dtype)
    denom = jnp.maximum(cnt, 1).astype(rows.dtype)
    w = mask / denom[:, None]  # [bn, K]
    # Weighted sum over the K neighbor slots; contracts on the MXU for
    # K multiples of 8 (einsum lowers to batched matmul).
    o_ref[...] = jnp.einsum("nk,nkf->nf", w, rows, preferred_element_type=rows.dtype)


def _bwd_kernel(g_ref, idx_ref, cnt_ref, o_ref):
    """One feature-block tile of the scatter-add backward."""
    g = g_ref[...]  # [n_dst, bf]
    idx = idx_ref[...]  # [n_dst, K]
    cnt = cnt_ref[...]  # [n_dst]
    n_dst, k = idx.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (n_dst, k), 1)
    mask = (lane < cnt[:, None]).astype(g.dtype)
    denom = jnp.maximum(cnt, 1).astype(g.dtype)
    w = mask / denom[:, None]  # [n_dst, K]
    contrib = g[:, None, :] * w[:, :, None]  # [n_dst, K, bf]
    zero = jnp.zeros(o_ref.shape, g.dtype)
    o_ref[...] = zero.at[idx.reshape(-1)].add(contrib.reshape(-1, g.shape[-1]))


def _pad2(a, n, f, fill=0):
    return jnp.pad(a, ((0, n - a.shape[0]), (0, f - a.shape[1])), constant_values=fill)


def mean_aggregate_fwd(
    features: jax.Array,
    idx: jax.Array,
    counts: jax.Array,
    *,
    block_n: int = BLOCK_N,
    block_f: int = BLOCK_F,
    interpret: bool = True,
) -> jax.Array:
    """Masked-mean neighbor aggregation (forward only, no VJP rule).

    Args:
      features: `[n_src, F]` float source-node features.
      idx: `[n_dst, K]` int32 neighbor indices into `features`. Slots at
        `k >= counts[i]` are padding and may hold any valid row index.
      counts: `[n_dst]` int32 number of valid neighbors per destination,
        in `[0, K]`.

    Returns:
      `[n_dst, F]` mean of the valid neighbor rows (zero where count == 0).
    """
    n_src, f = features.shape
    n_dst, k = idx.shape
    bn = min(block_n, _ceil_to(max(n_dst, 1), 8))
    bf = min(block_f, _ceil_to(max(f, 1), 8))
    np_, fp = _ceil_to(n_dst, bn), _ceil_to(f, bf)
    # Pad: extra dst rows get count 0 / idx 0, extra feature cols are sliced
    # off below, so padding is mathematically inert.
    idx_p = _pad2(idx, np_, k)
    cnt_p = jnp.pad(counts, (0, np_ - n_dst))
    x_p = _pad2(features, n_src, fp)

    out = pl.pallas_call(
        _fwd_kernel,
        grid=(np_ // bn, fp // bf),
        in_specs=[
            pl.BlockSpec((n_src, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, fp), features.dtype),
        interpret=interpret,
    )(x_p, idx_p, cnt_p)
    return out[:n_dst, :f]


def mean_aggregate_bwd(
    g: jax.Array,
    idx: jax.Array,
    counts: jax.Array,
    n_src: int,
    *,
    block_f: int = BLOCK_F,
    interpret: bool = True,
) -> jax.Array:
    """Backward of :func:`mean_aggregate_fwd` w.r.t. `features`.

    Scatter-adds `g[i] / counts[i]` into each valid neighbor row.
    """
    n_dst, f = g.shape
    k = idx.shape[1]
    bf = min(block_f, _ceil_to(max(f, 1), 8))
    fp = _ceil_to(f, bf)
    g_p = _pad2(g, n_dst, fp)

    out = pl.pallas_call(
        _bwd_kernel,
        grid=(fp // bf,),
        in_specs=[
            pl.BlockSpec((n_dst, bf), lambda j: (0, j)),
            pl.BlockSpec((n_dst, k), lambda j: (0, 0)),
            pl.BlockSpec((n_dst,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((n_src, bf), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_src, fp), g.dtype),
        interpret=interpret,
    )(g_p, idx, counts)
    return out[:, :f]


def mean_aggregate(
    features: jax.Array,
    idx: jax.Array,
    counts: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Differentiable masked-mean aggregation (Pallas fwd + Pallas bwd).

    The VJP w.r.t. `features` is the scatter-add kernel; `idx`/`counts` are
    integer-typed and non-differentiable (closed over, so `jax.grad` never
    sees them as primals).
    """
    n_src = features.shape[0]

    @jax.custom_vjp
    def agg(x):
        return mean_aggregate_fwd(x, idx, counts, interpret=interpret)

    def agg_fwd(x):
        return agg(x), None

    def agg_bwd(_, g):
        return (mean_aggregate_bwd(g, idx, counts, n_src, interpret=interpret),)

    agg.defvjp(agg_fwd, agg_bwd)
    return agg(features)


# Convenience partial used by model.py so every call site shares one config.
mean_aggregate_interp = partial(mean_aggregate, interpret=True)
