"""Pure-jnp oracles for the Pallas aggregation kernels.

These are the correctness ground truth: small, obviously-right expressions
with no tiling, padding, or pallas machinery. pytest sweeps the kernels
against them (see python/tests/test_kernel.py).
"""

import jax.numpy as jnp


def mean_aggregate_ref(features, idx, counts):
    """out[i] = mean over valid slots k < counts[i] of features[idx[i, k]]."""
    rows = features[idx]  # [n_dst, K, F]
    k = idx.shape[1]
    mask = (jnp.arange(k)[None, :] < counts[:, None]).astype(features.dtype)
    denom = jnp.maximum(counts, 1).astype(features.dtype)
    w = mask / denom[:, None]
    return (rows * w[..., None]).sum(axis=1)


def mean_aggregate_grad_ref(g, idx, counts, n_src):
    """d_features of mean_aggregate_ref: scatter-add of g[i]/counts[i]."""
    k = idx.shape[1]
    mask = (jnp.arange(k)[None, :] < counts[:, None]).astype(g.dtype)
    denom = jnp.maximum(counts, 1).astype(g.dtype)
    w = mask / denom[:, None]  # [n_dst, K]
    contrib = g[:, None, :] * w[:, :, None]  # [n_dst, K, F]
    out = jnp.zeros((n_src, g.shape[1]), g.dtype)
    return out.at[idx.reshape(-1)].add(contrib.reshape(-1, g.shape[1]))
