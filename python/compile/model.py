"""L2: GraphSAGE forward/backward in JAX, calling the L1 Pallas kernels.

The paper trains a 3-layer GraphSAGE (mean aggregator, hidden 256, dropout
between layers) on sampled message-flow-graphs (MFGs). This module defines
that model over *padded* MFGs so it can be AOT-lowered to fixed-shape HLO
(see aot.py) and executed from the rust coordinator via PJRT.

Padded MFG convention (mirrors DGL: destination nodes come first in the
source-node array of the level below):

  level L (top) .. level 0 (input); ``caps[l]`` is the padded node count of
  level l, ``caps[L] == batch``.  For layer ``l`` (1-indexed):
    idx_l:  [caps[l], K_l] int32 — neighbor slots into the level-(l-1) array
    cnt_l:  [caps[l]]      int32 — valid neighbor count per node (0 for padding)
  feats:    [caps[0], F] float32 — input features of level-0 nodes
  labels:   [batch] int32, label_mask: [batch] float32 (0 for padded seeds)

Padding is inert: padded nodes have cnt == 0 (aggregation yields 0), are
never referenced by valid idx slots, and are masked out of the loss.
"""

from functools import partial
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.sage_agg import mean_aggregate


class ModelConfig(NamedTuple):
    """Static configuration of one AOT model variant."""

    feat_dim: int
    hidden: int
    classes: int
    batch: int
    fanouts: Tuple[int, ...]  # (N_L, ..., N_1): top level first, paper §4.1
    caps: Tuple[int, ...]  # (caps[0], ..., caps[L]): input level first
    dropout: float = 0.5

    @property
    def layers(self) -> int:
        return len(self.fanouts)

    def layer_dims(self) -> Sequence[Tuple[int, int]]:
        dims = []
        d_in = self.feat_dim
        for l in range(self.layers):
            d_out = self.classes if l == self.layers - 1 else self.hidden
            dims.append((d_in, d_out))
            d_in = d_out
        return dims


def compute_caps(batch: int, fanouts: Sequence[int], node_limit: int | None = None) -> Tuple[int, ...]:
    """Worst-case padded node count per level.

    Level sets are unique and include the level above as a prefix, so
    ``caps[l-1] <= caps[l] * (1 + N_l)`` and never more than the graph size.
    Returned input-level-first: ``(caps[0], ..., caps[L])``.
    """
    caps = [batch]
    for f in fanouts:  # fanouts is top-first: N_L, ..., N_1
        nxt = caps[-1] * (1 + f)
        if node_limit is not None:
            nxt = min(nxt, node_limit)
        caps.append(nxt)
    return tuple(reversed(caps))


def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the contract with the rust side."""
    spec = []
    for l, (d_in, d_out) in enumerate(cfg.layer_dims(), start=1):
        spec.append((f"l{l}.w_self", (d_in, d_out)))
        spec.append((f"l{l}.w_neigh", (d_in, d_out)))
        spec.append((f"l{l}.bias", (d_out,)))
    return spec


def init_params(cfg: ModelConfig, key: jax.Array):
    """Xavier-uniform init (reference only; rust owns init at runtime)."""
    params = []
    for _, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            params.append(jax.random.uniform(sub, shape, jnp.float32, -limit, limit))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def _sage_layer(w_self, w_neigh, bias, h_src, idx, cnt, n_dst):
    """One GraphSAGE-mean layer over a padded bipartite MFG level."""
    agg = mean_aggregate(h_src, idx, cnt)  # [n_dst, d_in] Pallas kernel
    h_dst = h_src[:n_dst]  # dst nodes are the prefix of the src array
    return h_dst @ w_self + agg @ w_neigh + bias


def forward(cfg: ModelConfig, params, feats, mfgs, *, train: bool, seed=None):
    """Run all layers; returns seed-node logits ``[batch, classes]``.

    ``mfgs`` is ``[(idx_1, cnt_1), ..., (idx_L, cnt_L)]`` bottom layer first
    (layer 1 consumes the input features).
    """
    h = feats
    for l in range(1, cfg.layers + 1):
        idx, cnt = mfgs[l - 1]
        w_self, w_neigh, bias = params[3 * (l - 1) : 3 * l]
        n_dst = cfg.caps[l]
        h = _sage_layer(w_self, w_neigh, bias, h, idx, cnt, n_dst)
        if l < cfg.layers:
            h = jax.nn.relu(h)
            if train and cfg.dropout > 0.0:
                key = jax.random.fold_in(jax.random.PRNGKey(seed), l)
                keep = 1.0 - cfg.dropout
                mask = jax.random.bernoulli(key, keep, h.shape)
                h = jnp.where(mask, h / keep, 0.0)
    return h


def masked_cross_entropy(logits, labels, label_mask):
    """Mean CE over valid seeds (mask 0 → padded seed, excluded)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(label_mask.sum(), 1.0)
    return (nll * label_mask).sum() / denom


def _unpack(cfg: ModelConfig, args):
    """Split the flat AOT argument list (see arg_order in the manifest)."""
    n_params = 3 * cfg.layers
    params = tuple(args[:n_params])
    rest = list(args[n_params:])
    feats = rest.pop(0)
    mfgs = []
    for _ in range(cfg.layers):
        idx = rest.pop(0)
        cnt = rest.pop(0)
        mfgs.append((idx, cnt))
    return params, feats, mfgs, rest


def make_train_step(cfg: ModelConfig):
    """Flat-signature train step: ``(*params, feats, idx*, cnt*, labels,
    label_mask, seed) -> (loss, *grads)``; grads in param_spec order."""

    def train_step(*args):
        params, feats, mfgs, rest = _unpack(cfg, args)
        labels, label_mask, seed = rest

        def loss_fn(p):
            logits = forward(cfg, p, feats, mfgs, train=True, seed=seed)
            return masked_cross_entropy(logits, labels, label_mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss,) + tuple(grads)

    return train_step


def make_eval_step(cfg: ModelConfig):
    """Flat-signature eval step: ``(*params, feats, idx*, cnt*) -> (logits,)``."""

    def eval_step(*args):
        params, feats, mfgs, rest = _unpack(cfg, args)
        assert not rest
        logits = forward(cfg, params, feats, mfgs, train=False)
        return (logits,)

    return eval_step


def example_args(cfg: ModelConfig, *, for_train: bool):
    """ShapeDtypeStructs for jax.jit(...).lower(...) of one variant."""
    f32 = jnp.float32
    i32 = jnp.int32
    args = [jax.ShapeDtypeStruct(s, f32) for _, s in param_spec(cfg)]
    args.append(jax.ShapeDtypeStruct((cfg.caps[0], cfg.feat_dim), f32))
    for l in range(1, cfg.layers + 1):
        k = cfg.fanouts[cfg.layers - l]  # fanouts are top-first
        args.append(jax.ShapeDtypeStruct((cfg.caps[l], k), i32))
        args.append(jax.ShapeDtypeStruct((cfg.caps[l],), i32))
    if for_train:
        args.append(jax.ShapeDtypeStruct((cfg.batch,), i32))  # labels
        args.append(jax.ShapeDtypeStruct((cfg.batch,), f32))  # label_mask
        args.append(jax.ShapeDtypeStruct((), i32))  # dropout seed
    return args


def arg_order(cfg: ModelConfig, *, for_train: bool):
    """Human/manifest-readable names matching example_args order."""
    names = [n for n, _ in param_spec(cfg)]
    names.append("feats")
    for l in range(1, cfg.layers + 1):
        names += [f"idx_{l}", f"cnt_{l}"]
    if for_train:
        names += ["labels", "label_mask", "seed"]
    return names
