"""AOT pipeline: lower the L2 GraphSAGE train/eval steps to HLO text.

Interchange format is HLO **text**, not ``serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the rust crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--variants a,b,...]

Emits, per variant:  <name>_train.hlo.txt, <name>_eval.hlo.txt
plus a single ``manifest.json`` describing shapes, caps, fanouts and the
flat argument order — the contract consumed by rust/src/runtime/.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import (
    ModelConfig,
    arg_order,
    compute_caps,
    example_args,
    make_eval_step,
    make_train_step,
    param_spec,
)

# ---------------------------------------------------------------------------
# Variant registry. Caps are worst-case (unique node sets, dst-prefix
# convention) optionally clamped by the dataset's node count — see
# compute_caps. Keep these in sync with rust configs (manifest is the truth).
# ---------------------------------------------------------------------------


def _variant(feat_dim, hidden, classes, batch, fanouts, node_limit=None, dropout=0.5):
    return ModelConfig(
        feat_dim=feat_dim,
        hidden=hidden,
        classes=classes,
        batch=batch,
        fanouts=tuple(fanouts),
        caps=compute_caps(batch, fanouts, node_limit),
        dropout=dropout,
    )


VARIANTS = {
    # Tiny config for unit tests / quickstart example.
    "quickstart": _variant(32, 64, 8, 32, (3, 3, 3)),
    # End-to-end training driver on products-sim (paper model: 3-layer
    # GraphSAGE, hidden 256; fanout reduced from (15,10,5) to keep the CPU
    # train step sub-second — see DESIGN.md §Substitutions).
    "e2e_products": _variant(100, 256, 47, 128, (5, 5, 5)),
    # Fig 6 distributed runs (per-worker batch; paper uses 1000).
    "fig6_products": _variant(100, 256, 47, 256, (5, 5, 5)),
    "fig6_papers": _variant(128, 256, 172, 256, (5, 5, 5)),
    # Ratio-corrected Fig 6 variants: this testbed has ~2 cores vs the
    # paper's 2x56-core Xeons, so hidden=256 makes GNN compute drown the
    # communication effects the figure is about. hidden=64 restores a
    # compute:communication ratio closer to the paper's (DESIGN.md
    # §Substitutions).
    "fig6_products_small": _variant(100, 64, 47, 256, (5, 5, 5)),
    "fig6_papers_small": _variant(128, 64, 172, 256, (5, 5, 5)),
    # Fig 5 end-to-end panel: larger batches on papers100m-sim.
    "fig5_b1024": _variant(128, 256, 172, 1024, (5, 5, 5)),
    "fig5_b2048": _variant(128, 256, 172, 2048, (5, 5, 5), node_limit=1_100_000),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, cfg: ModelConfig, out_dir: str) -> dict:
    entry = {
        "feat_dim": cfg.feat_dim,
        "hidden": cfg.hidden,
        "classes": cfg.classes,
        "batch": cfg.batch,
        "fanouts": list(cfg.fanouts),
        "caps": list(cfg.caps),
        "dropout": cfg.dropout,
        "params": [{"name": n, "shape": list(s)} for n, s in param_spec(cfg)],
    }
    for kind, make in (("train", make_train_step), ("eval", make_eval_step)):
        fname = f"{name}_{kind}.hlo.txt"
        lowered = jax.jit(make(cfg)).lower(*example_args(cfg, for_train=kind == "train"))
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry[f"{kind}_hlo"] = fname
        entry[f"{kind}_args"] = arg_order(cfg, for_train=kind == "train")
        print(f"  {fname}: {len(text) / 1e6:.2f} MB")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=None,
        help="comma-separated subset of variant names (default: all)",
    )
    args = ap.parse_args()

    names = list(VARIANTS) if args.variants is None else args.variants.split(",")
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"variants": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name in names:
        cfg = VARIANTS[name]
        print(f"lowering {name}: caps={cfg.caps}")
        manifest["variants"][name] = lower_variant(name, cfg, args.out_dir)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
